//! The TCP front-end: thread-per-connection serving of the length-
//! prefixed JSON protocol over one shared [`SessionCore`].
//!
//! Verbs (all requests are objects with a `"verb"` field):
//!
//! * `open`  — build (or re-attach to) an operator over a named synthetic
//!   dataset. Fields: `name` (`uniform`/`cube`/`sst`), `n`, `d`, `seed`,
//!   `kernel`, `p`, `theta`, `tol`, `leaf`, `precision`. Returns a small
//!   integer `id`. Two tenants opening the same spec get the same id —
//!   and therefore share one cached operator *and* one micro-batcher.
//! * `mvm`   — `{id, w, deadline_ms?, inject?}` → `{z}`. Routed through
//!   the operator's [`MicroBatcher`], so concurrent tenants coalesce
//!   into fused applies.
//! * `solve` — `{id, y, noise?, tol?, max_iters?, deadline_ms?}` → CG
//!   solution with convergence data. Solves run directly on the core
//!   (CG is iterative and session-side batching of solves is a
//!   different verb). Under deadline pressure the solve stops early and
//!   returns the partial iterate with `converged:false` and the
//!   achieved `rel_residual`.
//! * `stats` — session counters, registry stats, per-operator batching
//!   + breaker stats, fault counters, reliability config, SIMD backend.
//! * `close` — polite hangup.
//!
//! Every verb body runs under `catch_unwind`: a panic (bad geometry, a
//! non-square solve) becomes an `{"ok": false}` response for that tenant
//! and the server keeps serving the rest.
//!
//! ## Structured errors
//!
//! Reliability outcomes use stable `error` kinds so clients can react
//! without parsing prose: `overloaded` (+`retry_after_ms`,
//! `queue_depth`), `deadline_exceeded` (+`waited_ms`), `worker_panic`
//! (+`detail`), `breaker_open` (+`retry_after_ms`), `shutting_down`.
//! Each served operator has a [`CircuitBreaker`]: consecutive
//! `worker_panic` failures trip it, rejections answer instantly, a
//! half-open probe closes it again.
//!
//! Shutdown: `ServerHandle::shutdown` (in-process) or SIGINT (the CLI
//! installs a flag-setting handler) stops the accept loop, joins the
//! connection threads — whose reads time out frequently precisely so
//! they notice — then shuts every micro-batcher down, draining requests
//! still queued. In-flight work is answered, never dropped.

use super::batcher::{BatchConfig, BatchError, MicroBatcher, MvmRequest};
use super::breaker::{BreakerConfig, CircuitBreaker};
use super::faults::{panic_message, FaultConfig, Faults};
use super::json::Json;
use super::protocol::{frame_bytes, write_frame, FrameReader};
use crate::data;
use crate::kernels::Family;
use crate::points::Points;
use crate::rng::Pcg32;
use crate::session::{
    simd_backend, Backend, OpHandle, Precision, Session, SessionCore, SolveOpts, Subsets,
};
use std::collections::HashMap;
use std::io::{self, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

/// Lock with poison recovery — one panicking connection must not take
/// the whole server's op table with it.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// How often blocked reads and the accept loop wake to poll the
/// shutdown flag. Long enough to be free, short enough that Ctrl-C
/// feels immediate.
const POLL_INTERVAL: Duration = Duration::from_millis(200);

/// Server construction parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7878` (port 0 ⇒ ephemeral).
    pub addr: String,
    /// Session worker threads (0 ⇒ all cores).
    pub threads: usize,
    /// Near-field backend selection.
    pub backend: Backend,
    /// Operator-registry LRU capacity.
    pub registry_capacity: usize,
    /// Micro-batching knobs applied to every served operator
    /// (including the queue-depth admission cap).
    pub batch: BatchConfig,
    /// Per-operator circuit-breaker tuning.
    pub breaker: BreakerConfig,
    /// Fault injection (disabled unless configured via `FKT_FAULTS`
    /// or `--faults`).
    pub faults: FaultConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            threads: 0,
            backend: Backend::Auto,
            registry_capacity: 64,
            batch: BatchConfig::default(),
            breaker: BreakerConfig::default(),
            faults: FaultConfig::disabled(),
        }
    }
}

/// One served operator: the session handle plus its batching engine
/// and health breaker.
struct OpEntry {
    id: u64,
    handle: OpHandle,
    batcher: MicroBatcher,
    breaker: CircuitBreaker,
}

/// Operator table. Ids are small sequential integers — JSON numbers are
/// f64, so raw pointers would not survive the wire — and `by_ptr` maps
/// the underlying shared operator back to its id so tenants opening the
/// same spec share one entry (and one batcher).
#[derive(Default)]
struct OpsMap {
    by_ptr: HashMap<usize, u64>,
    by_id: HashMap<u64, Arc<OpEntry>>,
    next_id: u64,
}

type DatasetKey = (String, usize, usize, u64);

/// Shared server state, visible to every connection thread.
struct ServerState {
    core: Arc<SessionCore>,
    batch_cfg: BatchConfig,
    breaker_cfg: BreakerConfig,
    faults: Arc<Faults>,
    ops: Mutex<OpsMap>,
    /// Synthetic datasets are deterministic in `(name, n, d, seed)`, so
    /// re-opens skip regeneration.
    datasets: Mutex<HashMap<DatasetKey, Arc<Points>>>,
    shutdown: AtomicBool,
}

/// A bound, not-yet-running server. [`Server::run`] blocks on the accept
/// loop; [`Server::spawn`] runs it on a thread and hands back a handle.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

/// Control handle for a server spawned on a background thread.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    thread: Option<thread::JoinHandle<io::Result<()>>>,
}

impl Server {
    /// Build the session and bind the listener (nonblocking, so the
    /// accept loop can poll the shutdown flag).
    pub fn bind(cfg: &ServeConfig) -> io::Result<Server> {
        let session = Session::builder()
            .threads(cfg.threads)
            .backend(cfg.backend)
            .registry_capacity(cfg.registry_capacity)
            .build();
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let state = Arc::new(ServerState {
            core: session.clone_core(),
            batch_cfg: cfg.batch,
            breaker_cfg: cfg.breaker,
            faults: Arc::new(Faults::new(cfg.faults)),
            ops: Mutex::new(OpsMap::default()),
            datasets: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Bind and run on a background thread; the handle shuts it down.
    pub fn spawn(cfg: &ServeConfig) -> io::Result<ServerHandle> {
        let server = Server::bind(cfg)?;
        let addr = server.local_addr()?;
        let state = Arc::clone(&server.state);
        let thread = thread::Builder::new()
            .name("fkt-serve".to_string())
            .spawn(move || server.run())?;
        Ok(ServerHandle { addr, state, thread: Some(thread) })
    }

    /// Accept loop. Returns after a shutdown request (or SIGINT, when
    /// the handler is installed) once every connection thread has been
    /// joined and every micro-batcher drained.
    pub fn run(&self) -> io::Result<()> {
        let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
        while !self.state.shutdown.load(Ordering::SeqCst) && !sigint_pending() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let state = Arc::clone(&self.state);
                    let conn = thread::Builder::new()
                        .name("fkt-serve-conn".to_string())
                        .spawn(move || serve_connection(stream, &state))?;
                    conns.push(conn);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // Nothing pending: nap briefly (short, so connects
                    // are picked up promptly) and re-check the flag.
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
            conns.retain(|c| !c.is_finished());
        }
        // Graceful drain: stop the connection threads first (they poll
        // the flag via read timeouts), then let every batcher answer
        // whatever is still queued before we return.
        self.state.shutdown.store(true, Ordering::SeqCst);
        for conn in conns {
            let _ = conn.join();
        }
        let ops = lock(&self.state.ops);
        for entry in ops.by_id.values() {
            entry.batcher.shutdown();
        }
        Ok(())
    }
}

impl ServerHandle {
    /// The address clients should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown and wait for the drain to finish.
    pub fn shutdown(mut self) -> io::Result<()> {
        self.stop();
        match self.thread.take() {
            Some(t) => t.join().unwrap_or_else(|_| Err(io::Error::other("server panicked"))),
            None => Ok(()),
        }
    }

    fn stop(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// One connection: read frames until hangup or shutdown, answering each
/// request in order. Read timeouts are the shutdown polling mechanism —
/// the resumable `FrameReader` keeps partial frames across them.
fn serve_connection(stream: TcpStream, state: &Arc<ServerState>) {
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(POLL_INTERVAL)).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = FrameReader::new(BufReader::new(stream));
    while !state.shutdown.load(Ordering::SeqCst) {
        match reader.read_frame() {
            Ok(Some(request)) => {
                // Injected connection drop: vanish without answering —
                // the client's retry path owns recovery.
                if state.faults.drop_connection() {
                    break;
                }
                let (response, hangup) = handle_request(state, &request);
                // The response goes out as raw bytes so the fault layer
                // can corrupt the frame in flight; a corrupted frame is
                // followed by hangup (real corruption rarely leaves a
                // healthy connection behind).
                let mut bytes = frame_bytes(&response);
                let corrupted = state.faults.corrupt_frame(&mut bytes);
                let sent = writer.write_all(&bytes).and_then(|()| writer.flush()).is_ok();
                if !sent || hangup || corrupted {
                    break;
                }
            }
            Ok(None) => break, // peer closed cleanly
            // Poll tick; the reader retains any partial frame.
            Err(e) if is_timeout(&e) => continue,
            Err(e) => {
                // Framing/JSON garbage: tell the peer why, then hang up
                // (the stream can no longer be trusted to re-sync).
                let _ = write_frame(&mut writer, &err_response(&e.to_string()));
                break;
            }
        }
    }
}

/// Dispatch one request. The bool says whether to hang up afterwards.
fn handle_request(state: &Arc<ServerState>, request: &Json) -> (Json, bool) {
    let verb = request.get("verb").and_then(Json::as_str).unwrap_or("").to_string();
    if verb == "close" {
        return (ok_response(vec![("bye", Json::Bool(true))]), true);
    }
    let outcome = catch_unwind(AssertUnwindSafe(|| match verb.as_str() {
        "open" => open_verb(state, request),
        "mvm" => mvm_verb(state, request),
        "solve" => solve_verb(state, request),
        "stats" => Ok(stats_verb(state)),
        other => Err(format!("unknown verb {other:?}")),
    }));
    let response = match outcome {
        Ok(Ok(response)) => response,
        Ok(Err(message)) => err_response(&message),
        Err(payload) => {
            err_response(&format!("internal panic: {}", panic_message(payload.as_ref())))
        }
    };
    (response, false)
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn ok_response(fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok".to_string(), Json::Bool(true))];
    pairs.extend(fields.into_iter().map(|(k, v)| (k.to_string(), v)));
    Json::Obj(pairs)
}

fn err_response(message: &str) -> Json {
    err_with(message, vec![])
}

/// Structured error: a stable `error` kind plus machine-readable
/// fields (`retry_after_ms`, `waited_ms`, …).
fn err_with(kind: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("ok".to_string(), Json::Bool(false)),
        ("error".to_string(), Json::str(kind)),
    ];
    pairs.extend(fields.into_iter().map(|(k, v)| (k.to_string(), v)));
    Json::Obj(pairs)
}

/// Map a batcher error onto the wire contract.
fn batch_error_response(err: &BatchError) -> Json {
    let fields = match err {
        BatchError::Overloaded { queue_depth, retry_after_ms } => vec![
            ("retry_after_ms", Json::Num(*retry_after_ms as f64)),
            ("queue_depth", Json::Num(*queue_depth as f64)),
        ],
        BatchError::DeadlineExceeded { waited_ms } => {
            vec![("waited_ms", Json::Num(*waited_ms as f64))]
        }
        BatchError::WorkerPanic(detail) => vec![("detail", Json::str(detail))],
        BatchError::Shutdown => vec![],
    };
    err_with(err.kind(), fields)
}

/// Parse a request's `deadline_ms` into an absolute instant. A
/// non-positive deadline is already expired — answered deterministically
/// (`Err` carries the ready-made response) without touching the queue,
/// which is what lets the probe assert this path against any server.
fn request_deadline(request: &Json) -> Result<Option<Instant>, Json> {
    match request.get("deadline_ms").and_then(Json::as_f64) {
        None => Ok(None),
        Some(ms) if ms.is_nan() || ms <= 0.0 => {
            Err(err_with("deadline_exceeded", vec![("waited_ms", Json::Num(0.0))]))
        }
        // Cap at a day: a deadline that far out is "no deadline", and
        // the cap keeps Duration::from_secs_f64 off its panic paths.
        Some(ms) => Ok(Some(Instant::now() + Duration::from_secs_f64((ms / 1e3).min(86_400.0)))),
    }
}

/// Field helpers: JSON numbers with defaults and range sanity.
fn get_usize(request: &Json, key: &str, default: usize) -> usize {
    request.get(key).and_then(Json::as_usize).unwrap_or(default)
}

fn get_f64(request: &Json, key: &str, default: f64) -> f64 {
    request.get(key).and_then(Json::as_f64).unwrap_or(default)
}

/// `open`: materialize the dataset (cached), build or re-attach to the
/// operator, and hand back its id. With a `subsets` field the operator is
/// the additive (ANOVA) composite over those feature subsets
/// (`"random:KxA"` or explicit `"0,1;2,3"` — same spelling as the CLI),
/// which lifts the dimension cap: each term only ever runs the FKT at its
/// own subset arity, so `d` may go up to 32.
fn open_verb(state: &Arc<ServerState>, request: &Json) -> Result<Json, String> {
    let name = request.get("name").and_then(Json::as_str).unwrap_or("uniform").to_string();
    let n = get_usize(request, "n", 10_000);
    let d = if name == "sst" { 3 } else { get_usize(request, "d", 3) };
    let seed = get_usize(request, "seed", 1) as u64;
    let subsets = match request.get("subsets").and_then(Json::as_str) {
        Some(text) => Some(Subsets::parse(text)?),
        None => None,
    };
    let d_max = if subsets.is_some() { 32 } else { 10 };
    if n == 0 || !(1..=d_max).contains(&d) {
        return Err(format!("bad dataset shape n={n} d={d} (max d {d_max})"));
    }
    let pts = dataset(state, &name, n, d, seed)?;
    let family_name = request.get("kernel").and_then(Json::as_str).unwrap_or("matern32");
    let family = Family::from_name(family_name)
        .ok_or_else(|| format!("unknown kernel family {family_name:?}"))?;
    let precision_name = request.get("precision").and_then(Json::as_str).unwrap_or("auto");
    let precision = Precision::from_name(precision_name)
        .ok_or_else(|| format!("unknown precision tier {precision_name:?}"))?;
    let leaf = get_usize(request, "leaf", 512);
    let tol = request.get("tol").and_then(Json::as_f64);
    let (handle, terms) = match subsets {
        Some(subsets) => {
            // Validate (and pin) the axis lists up front so a bad request
            // is a structured wire error, not a handler panic.
            let subs = subsets.materialize(d, seed)?;
            let terms = subs.len();
            let mut spec = state
                .core
                .additive(&pts)
                .kernel(family)
                .leaf_capacity(leaf)
                .precision(precision)
                .subsets(Subsets::Explicit(subs));
            match tol {
                Some(eps) => spec = spec.tolerance(eps),
                None => {
                    let cfg = crate::fkt::FktConfig {
                        p: get_usize(request, "p", 4),
                        theta: get_f64(request, "theta", 0.5),
                        leaf_capacity: leaf,
                        ..Default::default()
                    };
                    spec = spec.config(cfg);
                }
            }
            (spec.build(), terms)
        }
        None => {
            let mut spec = state
                .core
                .operator(&pts)
                .kernel(family)
                .leaf_capacity(leaf)
                .precision(precision);
            match tol {
                Some(eps) => spec = spec.tolerance(eps),
                None => {
                    spec = spec
                        .order(get_usize(request, "p", 4))
                        .theta(get_f64(request, "theta", 0.5));
                }
            }
            (spec.build(), 0)
        }
    };
    let entry = register_op(state, handle);
    let mut fields = vec![
        ("id", Json::Num(entry.id as f64)),
        ("n", Json::Num(entry.handle.num_sources() as f64)),
        ("d", Json::Num(d as f64)),
        ("kernel", Json::str(family.name())),
        ("p", Json::Num(entry.handle.order() as f64)),
        ("theta", Json::Num(entry.handle.theta())),
        ("precision", Json::str(entry.handle.precision().name())),
    ];
    if terms > 0 {
        fields.push(("terms", Json::Num(terms as f64)));
    }
    Ok(ok_response(fields))
}

/// Dataset cache lookup/build. The map lock is held across generation,
/// which serializes concurrent first-opens of the *same* dataset
/// (desired — generate once) at the cost of briefly serializing
/// distinct first-opens (rare, and generation is millisecond-scale;
/// the expensive part of `open` is the operator build, which has its
/// own coalescing in the registry).
fn dataset(
    state: &Arc<ServerState>,
    name: &str,
    n: usize,
    d: usize,
    seed: u64,
) -> Result<Arc<Points>, String> {
    let key = (name.to_string(), n, d, seed);
    let mut cache = lock(&state.datasets);
    if let Some(pts) = cache.get(&key) {
        return Ok(Arc::clone(pts));
    }
    let mut rng = Pcg32::seeded(seed);
    let pts = match name {
        "uniform" | "sphere" => data::uniform_hypersphere(n, d, &mut rng),
        "cube" => data::uniform_cube(n, d, &mut rng),
        "sst" => data::sst::simulate(7.0, n, &mut rng).unit_sphere_points(),
        other => return Err(format!("unknown dataset {other:?} (uniform, cube, sst)")),
    };
    let pts = Arc::new(pts);
    cache.insert(key, Arc::clone(&pts));
    Ok(pts)
}

/// Intern the handle in the op table. Handles aliasing one cached
/// operator get one entry — and one shared micro-batcher, which is what
/// makes cross-*tenant* batching work.
fn register_op(state: &Arc<ServerState>, handle: OpHandle) -> Arc<OpEntry> {
    let ptr = Arc::as_ptr(handle.op()) as *const () as usize;
    let mut ops = lock(&state.ops);
    if let Some(id) = ops.by_ptr.get(&ptr) {
        if let Some(entry) = ops.by_id.get(id) {
            return Arc::clone(entry);
        }
    }
    ops.next_id += 1;
    let id = ops.next_id;
    let batcher = MicroBatcher::with_faults(
        Arc::clone(&state.core),
        handle.clone(),
        state.batch_cfg,
        Arc::clone(&state.faults),
    );
    let breaker = CircuitBreaker::new(state.breaker_cfg);
    let entry = Arc::new(OpEntry { id, handle, batcher, breaker });
    ops.by_ptr.insert(ptr, id);
    ops.by_id.insert(id, Arc::clone(&entry));
    entry
}

fn lookup_op(state: &Arc<ServerState>, request: &Json) -> Result<Arc<OpEntry>, String> {
    let id = request
        .get("id")
        .and_then(Json::as_usize)
        .ok_or_else(|| "missing operator id".to_string())? as u64;
    let ops = lock(&state.ops);
    ops.by_id.get(&id).cloned().ok_or_else(|| format!("no open operator with id {id}"))
}

/// `mvm`: through the operator's micro-batcher, where concurrent
/// tenants coalesce. Reliability outcomes — breaker rejection, shed,
/// expired deadline, worker panic — come back as structured errors.
fn mvm_verb(state: &Arc<ServerState>, request: &Json) -> Result<Json, String> {
    let entry = lookup_op(state, request)?;
    let w = request
        .get("w")
        .and_then(Json::f64s)
        .ok_or_else(|| "mvm needs a numeric weight array w".to_string())?;
    let n = entry.handle.num_sources();
    if w.len() != n {
        return Err(format!("w has {} entries; operator has {} sources", w.len(), n));
    }
    let deadline = match request_deadline(request) {
        Ok(deadline) => deadline,
        Err(expired) => return Ok(expired),
    };
    let inject_panic = request.get("inject").and_then(Json::as_str) == Some("panic");
    if inject_panic && !state.faults.inject_enabled() {
        return Err("inject requires a fault config with inject=1".to_string());
    }
    if let Err(retry_after_ms) = entry.breaker.try_admit() {
        return Ok(err_with(
            "breaker_open",
            vec![("retry_after_ms", Json::Num(retry_after_ms as f64))],
        ));
    }
    match entry.batcher.request(MvmRequest { w, deadline, inject_panic }) {
        Ok(z) => {
            entry.breaker.on_success();
            Ok(ok_response(vec![("z", Json::from_f64s(&z))]))
        }
        Err(err) => {
            // Only a panicked apply is an operator-health signal; shed
            // and expired requests say nothing about the operator.
            match err {
                BatchError::WorkerPanic(_) => entry.breaker.on_failure(),
                _ => entry.breaker.on_neutral(),
            }
            Ok(batch_error_response(&err))
        }
    }
}

/// `solve`: CG directly on the shared core (iterative; not batched).
fn solve_verb(state: &Arc<ServerState>, request: &Json) -> Result<Json, String> {
    let entry = lookup_op(state, request)?;
    let y = request
        .get("y")
        .and_then(Json::f64s)
        .ok_or_else(|| "solve needs a numeric right-hand side y".to_string())?;
    let n = entry.handle.num_sources();
    if y.len() != n {
        return Err(format!("y has {} entries; operator has {} sources", y.len(), n));
    }
    let deadline = match request_deadline(request) {
        Ok(deadline) => deadline,
        Err(expired) => return Ok(expired),
    };
    let inject_panic = request.get("inject").and_then(Json::as_str) == Some("panic");
    if inject_panic && !state.faults.inject_enabled() {
        return Err("inject requires a fault config with inject=1".to_string());
    }
    if let Err(retry_after_ms) = entry.breaker.try_admit() {
        return Ok(err_with(
            "breaker_open",
            vec![("retry_after_ms", Json::Num(retry_after_ms as f64))],
        ));
    }
    let noise = request.get("noise").and_then(Json::as_f64).map(|v| vec![v; n]);
    let max_iters = get_usize(request, "max_iters", 200);
    let opts = SolveOpts {
        tol: get_f64(request, "tol", 1e-6),
        max_iters,
        jitter: get_f64(request, "jitter", 1e-8),
        noise: noise.as_deref(),
        precondition: true,
        deadline,
    };
    // Panics (including injected faults) feed the breaker, so a sick
    // operator's solves trip it just like its mvms do.
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if inject_panic {
            state.faults.injected_panic();
        }
        state.faults.before_apply();
        state.core.solve(&entry.handle, &y, &opts)
    }));
    match outcome {
        Ok(result) => {
            entry.breaker.on_success();
            // Unconverged with iterations to spare means the deadline
            // (not the iteration budget) stopped the solve.
            let deadline_hit =
                deadline.is_some() && !result.converged && result.iterations < max_iters;
            Ok(ok_response(vec![
                ("x", Json::from_f64s(&result.x)),
                ("iterations", Json::Num(result.iterations as f64)),
                ("rel_residual", Json::Num(result.rel_residual)),
                ("converged", Json::Bool(result.converged)),
                ("deadline_hit", Json::Bool(deadline_hit)),
            ]))
        }
        Err(payload) => {
            entry.breaker.on_failure();
            Ok(err_with(
                "worker_panic",
                vec![("detail", Json::str(&panic_message(payload.as_ref())))],
            ))
        }
    }
}

/// `stats`: one snapshot of everything a load test wants to know.
fn stats_verb(state: &Arc<ServerState>) -> Json {
    let c = state.core.counters();
    let counters = Json::Obj(vec![
        ("mvm".to_string(), Json::Num(c.mvm as f64)),
        ("mvm_batch".to_string(), Json::Num(c.mvm_batch as f64)),
        ("solve".to_string(), Json::Num(c.solve as f64)),
        ("solve_batch".to_string(), Json::Num(c.solve_batch as f64)),
        ("refine_sweeps".to_string(), Json::Num(c.refine_sweeps as f64)),
    ]);
    let r = state.core.registry_stats();
    let registry = Json::Obj(vec![
        ("hits".to_string(), Json::Num(r.hits as f64)),
        ("misses".to_string(), Json::Num(r.misses as f64)),
        ("coalesced".to_string(), Json::Num(r.coalesced as f64)),
        ("evictions".to_string(), Json::Num(r.evictions as f64)),
        ("build_seconds".to_string(), Json::Num(r.build_seconds)),
        ("len".to_string(), Json::Num(r.len as f64)),
    ]);
    let ops = lock(&state.ops);
    let mut per_op: Vec<Json> = Vec::with_capacity(ops.by_id.len());
    let mut ids: Vec<&u64> = ops.by_id.keys().collect();
    ids.sort();
    for id in ids {
        let entry = &ops.by_id[id];
        let s = entry.batcher.stats();
        let b = entry.breaker.snapshot();
        let breaker = Json::Obj(vec![
            ("state".to_string(), Json::str(b.state.name())),
            ("consecutive_failures".to_string(), Json::Num(b.consecutive_failures as f64)),
            ("trips".to_string(), Json::Num(b.trips as f64)),
            ("rejected".to_string(), Json::Num(b.rejected as f64)),
        ]);
        per_op.push(Json::Obj(vec![
            ("id".to_string(), Json::Num(entry.id as f64)),
            ("n".to_string(), Json::Num(entry.handle.num_sources() as f64)),
            ("requests".to_string(), Json::Num(s.requests as f64)),
            ("applies".to_string(), Json::Num(s.applies as f64)),
            ("batched_applies".to_string(), Json::Num(s.batched_applies as f64)),
            ("batched_columns".to_string(), Json::Num(s.batched_columns as f64)),
            ("max_batch_columns".to_string(), Json::Num(s.max_batch_columns as f64)),
            ("columns_per_apply".to_string(), Json::Num(s.columns_per_apply())),
            ("queue_depth".to_string(), Json::Num(s.queue_depth as f64)),
            ("shed_overload".to_string(), Json::Num(s.shed_overload as f64)),
            ("expired_deadline".to_string(), Json::Num(s.expired_deadline as f64)),
            ("worker_panics".to_string(), Json::Num(s.worker_panics as f64)),
            ("breaker".to_string(), breaker),
        ]));
    }
    let f = state.faults.stats();
    let faults = Json::Obj(vec![
        ("active".to_string(), Json::Bool(state.faults.config().is_active())),
        ("injected_panics".to_string(), Json::Num(f.injected_panics as f64)),
        ("injected_latency".to_string(), Json::Num(f.injected_latency as f64)),
        ("dropped_connections".to_string(), Json::Num(f.dropped_connections as f64)),
        ("corrupted_frames".to_string(), Json::Num(f.corrupted_frames as f64)),
    ]);
    // The reliability knobs, so probes and soaks can read the limits
    // they are asserting against instead of hard-coding them.
    let config = Json::Obj(vec![
        ("max_columns".to_string(), Json::Num(state.batch_cfg.max_columns as f64)),
        ("window_us".to_string(), Json::Num(state.batch_cfg.gather_window.as_micros() as f64)),
        ("queue_cap".to_string(), Json::Num(state.batch_cfg.max_queue as f64)),
        (
            "breaker_failure_threshold".to_string(),
            Json::Num(state.breaker_cfg.failure_threshold as f64),
        ),
        (
            "breaker_cooldown_ms".to_string(),
            Json::Num(state.breaker_cfg.cooldown.as_millis() as f64),
        ),
    ]);
    // Shared worker-pool counters: all zeros on a single-threaded core
    // (no pool exists), and `tasks > 0` after the first pooled apply is
    // the load test's proof that serving never spawns per-apply threads.
    let p = state.core.pool_stats();
    let pool = Json::Obj(vec![
        ("batches".to_string(), Json::Num(p.batches as f64)),
        ("tasks".to_string(), Json::Num(p.tasks as f64)),
        ("steals".to_string(), Json::Num(p.steals as f64)),
        ("parks".to_string(), Json::Num(p.parks as f64)),
        ("unparks".to_string(), Json::Num(p.unparks as f64)),
        ("steal_ratio".to_string(), Json::Num(p.steal_ratio())),
    ]);
    ok_response(vec![
        ("counters", counters),
        ("registry", registry),
        ("ops", Json::Arr(per_op)),
        ("faults", faults),
        ("config", config),
        ("pool", pool),
        ("threads", Json::Num(state.core.threads() as f64)),
        ("simd_backend", Json::str(simd_backend().name())),
    ])
}

#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub(super) static SIGINT: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_signum: i32) {
        // Only async-signal-safe work here: set the flag; the accept
        // loop and connection reads poll it within POLL_INTERVAL.
        SIGINT.store(true, Ordering::SeqCst);
    }

    extern "C" {
        /// POSIX `signal(2)`. Declared locally — the crate takes no
        /// libc dependency for one syscall.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub(super) fn install() {
        const SIGINT_NUM: i32 = 2;
        unsafe {
            signal(SIGINT_NUM, on_sigint);
        }
    }
}

/// Arm graceful Ctrl-C: after this, SIGINT flips a flag that
/// [`Server::run`] polls, so the process drains and exits 0 instead of
/// dying mid-batch. No-op on non-unix targets.
pub fn install_sigint() {
    #[cfg(unix)]
    sig::install();
}

#[cfg(unix)]
fn sigint_pending() -> bool {
    sig::SIGINT.load(Ordering::SeqCst)
}

#[cfg(not(unix))]
fn sigint_pending() -> bool {
    false
}
