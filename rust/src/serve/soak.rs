//! Soak/chaos load driver: many clients, many requests, full outcome
//! accounting.
//!
//! The happy-path probe answers "does it work"; the soak driver
//! answers the reliability question — *under faults and overload, does
//! every request still come back framed?* It hammers a serve endpoint
//! with `clients × requests_per_client` MVMs (optionally carrying
//! deadlines), retries transport breaks and backpressure through
//! [`Client::call_retry`], and tallies every final outcome into a
//! [`SoakReport`]: successes, each structured error kind, transport
//! failures, and hangs (reads that hit the client timeout — the one
//! outcome a correct server never produces).
//!
//! The same driver backs the `fkt serve-soak` subcommand, the chaos
//! integration test, and the `serve_load` bench's chaos leg, so the
//! CI smoke and the local repro are literally the same code path.

use crate::rng::Pcg32;
use crate::serve::json::Json;
use crate::serve::protocol::{msg, Client, RetryPolicy};
use std::net::SocketAddr;
use std::sync::Barrier;
use std::time::{Duration, Instant};

/// One soak run's shape.
#[derive(Clone, Debug)]
pub struct SoakConfig {
    /// Concurrent client connections.
    pub clients: usize,
    /// MVM requests each client issues.
    pub requests_per_client: usize,
    /// The `open` request every client sends first (identical specs
    /// intern to one served operator).
    pub open: Json,
    /// Weight-vector length (the opened operator's source count).
    pub weight_len: usize,
    /// Optional per-request deadline to propagate.
    pub deadline_ms: Option<f64>,
    /// Client read timeout — the hang detector. A request whose final
    /// outcome is a timeout counts as `hung`.
    pub timeout: Duration,
    /// Retry policy for transport breaks and backpressure.
    pub retry: RetryPolicy,
    /// Seed for the per-client weight streams.
    pub seed: u64,
}

impl Default for SoakConfig {
    fn default() -> SoakConfig {
        SoakConfig {
            clients: 8,
            requests_per_client: 16,
            open: msg("open", &[]),
            weight_len: 0,
            deadline_ms: None,
            timeout: Duration::from_secs(10),
            retry: RetryPolicy::default(),
            seed: 0x50af,
        }
    }
}

/// Final-outcome tallies for one soak run. `total` counts issued MVM
/// requests; every one lands in exactly one bucket below it.
#[derive(Clone, Debug, Default)]
pub struct SoakReport {
    /// MVM requests issued.
    pub total: u64,
    /// Requests answered `ok:true` with a well-formed result.
    pub ok: u64,
    /// Final answer was the structured `overloaded` shed.
    pub overloaded: u64,
    /// Final answer was `deadline_exceeded`.
    pub deadline_exceeded: u64,
    /// Final answer was `worker_panic` (fault injection landed).
    pub worker_panic: u64,
    /// Final answer was `breaker_open`.
    pub breaker_open: u64,
    /// Any other `ok:false` response (bad id, malformed, …).
    pub other_error: u64,
    /// Transport errors that survived every retry (EOF, refused).
    pub transport_failures: u64,
    /// Requests whose final outcome was a read timeout — a hang.
    pub hung: u64,
    /// Clients whose `open` never succeeded (their requests are not
    /// issued and do not count toward `total`).
    pub open_failures: u64,
    /// Wall latency of each *successful* request, ms (includes retries).
    pub latencies_ms: Vec<f64>,
}

impl SoakReport {
    /// Requests whose final outcome was a framed response (success or
    /// structured error). The reliability contract says this equals
    /// `total`.
    pub fn framed(&self) -> u64 {
        self.total - self.transport_failures - self.hung
    }

    /// Fraction of requests not answered `ok:true`.
    pub fn error_rate(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        (self.total - self.ok) as f64 / self.total as f64
    }

    /// Fraction of requests whose final answer was the overload shed.
    pub fn shed_rate(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.overloaded as f64 / self.total as f64
    }

    /// p99 of successful-request latency, ms (0 when nothing succeeded).
    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.99)
    }

    /// p50 of successful-request latency, ms.
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.50)
    }

    fn absorb(&mut self, other: SoakReport) {
        self.total += other.total;
        self.ok += other.ok;
        self.overloaded += other.overloaded;
        self.deadline_exceeded += other.deadline_exceeded;
        self.worker_panic += other.worker_panic;
        self.breaker_open += other.breaker_open;
        self.other_error += other.other_error;
        self.transport_failures += other.transport_failures;
        self.hung += other.hung;
        self.open_failures += other.open_failures;
        self.latencies_ms.extend(other.latencies_ms);
    }
}

fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run one soak: spawn the clients, drive the load, merge the tallies.
pub fn run(addr: SocketAddr, cfg: &SoakConfig) -> SoakReport {
    let barrier = Barrier::new(cfg.clients);
    let reports: Vec<SoakReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|c| {
                let barrier = &barrier;
                scope.spawn(move || drive_client(addr, cfg, c, barrier))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("soak client thread")).collect()
    });
    let mut merged = SoakReport::default();
    for r in reports {
        merged.absorb(r);
    }
    merged
}

fn drive_client(addr: SocketAddr, cfg: &SoakConfig, index: usize, barrier: &Barrier) -> SoakReport {
    let mut report = SoakReport::default();
    let mut rng = Pcg32::seeded(cfg.seed.wrapping_add(index as u64));
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            barrier.wait();
            report.open_failures += 1;
            return report;
        }
    };
    let _ = client.set_timeout(Some(cfg.timeout));
    let id = client
        .call_retry(&cfg.open, &cfg.retry)
        .ok()
        .filter(|r| r.get("ok").and_then(Json::as_bool) == Some(true))
        .and_then(|r| r.get("id").and_then(Json::as_usize));
    let id = match id {
        Some(id) => id as f64,
        None => {
            barrier.wait();
            report.open_failures += 1;
            return report;
        }
    };
    barrier.wait();
    for _ in 0..cfg.requests_per_client {
        let w = rng.normal_vec(cfg.weight_len);
        let mut fields = vec![("id", Json::Num(id)), ("w", Json::from_f64s(&w))];
        if let Some(ms) = cfg.deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms)));
        }
        let request = msg("mvm", &fields);
        report.total += 1;
        let started = Instant::now();
        match client.call_retry(&request, &cfg.retry) {
            Ok(response) => {
                if response.get("ok").and_then(Json::as_bool) == Some(true) {
                    report.ok += 1;
                    report.latencies_ms.push(started.elapsed().as_secs_f64() * 1e3);
                } else {
                    match response.get("error").and_then(Json::as_str) {
                        Some("overloaded") => report.overloaded += 1,
                        Some("deadline_exceeded") => report.deadline_exceeded += 1,
                        Some("worker_panic") => report.worker_panic += 1,
                        Some("breaker_open") => report.breaker_open += 1,
                        _ => report.other_error += 1,
                    }
                }
            }
            Err(e) => match e.kind() {
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => {
                    report.hung += 1;
                    // The connection is desynced mid-frame; start clean
                    // so one hang doesn't cascade.
                    let _ = client.reconnect();
                }
                _ => report.transport_failures += 1,
            },
        }
    }
    report
}
