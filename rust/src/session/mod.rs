//! The session layer — the crate's public entry point.
//!
//! A [`Session`] owns everything a kernel-summation service needs between
//! requests: the [`Coordinator`] (threads, backend selection, metrics), a
//! keyed **operator registry** that caches built operators across requests
//! (see [`registry`]), and a tolerance-resolution cache (see [`tune`]).
//! Consumers never construct `FktOperator`s or talk to the coordinator
//! directly; they describe *what* they want and the session decides *how*:
//!
//! ```no_run
//! use fkt::kernels::Family;
//! use fkt::session::{Session, SolveOpts};
//! # let pts = fkt::points::Points::new(2, vec![0.0; 20]);
//! # let w = vec![0.0; 10];
//! # let y = vec![0.0; 10];
//! let session = Session::builder().threads(4).build();
//! let op = session
//!     .operator(&pts)
//!     .kernel(Family::Matern52)
//!     .tolerance(1e-6) // ← the paper's controllable-accuracy dial
//!     .build();
//! let z = session.mvm(&op, &w);                    // fast MVM
//! let sol = session.solve(&op, &y, &SolveOpts::default()); // CG solve
//! ```
//!
//! Four verbs cover every workload in the crate: [`Session::mvm`] /
//! [`Session::mvm_batch`] for products, and [`Session::solve`] /
//! [`Session::solve_batch`] for the linear systems GP regression and
//! training need — promoted to first-class verbs so "apply the inverse"
//! is as ordinary as "apply the matrix". The batched solve runs `m`
//! right-hand sides in one lockstep block-CG whose every iteration is a
//! single fused traversal, sharing one leaf-block-Jacobi factorization
//! across all columns — the workhorse behind `gp::train`'s
//! Hutchinson-probe estimators.
//!
//! Requests are expressed through the [`OpSpec`] builder. Its headline
//! knob is `.tolerance(ε)`: instead of hand-picking `(p, θ)` the caller
//! states the accuracy they need and the session resolves the cheapest
//! hyperparameters whose Lemma 4.1 truncation bound meets ε (explicit
//! `.order(p)` / `.theta(t)` still override). Identical requests against
//! identical data return the *same* cached operator — pointer-equal
//! `Arc`s — so a service answering many queries over one dataset builds
//! its tree/plan/expansion once.

pub mod registry;
pub mod tune;

pub use crate::coordinator::{Backend, MvmMetrics};
pub use crate::linalg::simd::{backend as simd_backend, SimdBackend};
pub use crate::linalg::Precision;
pub use registry::RegistryStats;
pub use tune::{
    auto_precision, max_order, resolve as resolve_tolerance, split_tolerance, Resolved,
    F32_AUTO_MIN_EPS, THETA_CANDIDATES,
};

use crate::baselines::DenseOperator;
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::fkt::{ExpansionCenter, FktConfig, FktOperator};
use crate::kernels::{Family, Kernel};
use crate::linalg::{
    cholesky, cholesky_solve, preconditioned_cg_batch_budgeted, preconditioned_cg_budgeted,
    vecops, BatchCgResult, CgBudget, CgResult, Mat,
};
use crate::op::composite::{SharedTermOp, SumOp};
use crate::op::KernelOp;
use crate::points::Points;
use crate::pool::PoolStats;
use crate::rng::Pcg32;
use registry::{composite_fingerprint, fingerprint, projection_fingerprint, OpKey, Registry};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Recover a mutex guard even if a panicking thread poisoned it: the
/// session's locked state (the tune cache) is a pure memo — worst case a
/// poisoned insert is simply recomputed — and a shared serving core must
/// not let one panicked request wedge every other tenant.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Default maximum number of cached operators per session.
const DEFAULT_REGISTRY_CAPACITY: usize = 64;

/// Tolerance-resolution cache flush threshold (entries are a few dozen
/// bytes, so this bounds the map at trivial memory while still caching
/// every realistic steady-state request mix).
const TUNE_CACHE_FLUSH: usize = 1024;

/// Inner-CG tolerance floor of the mixed-precision refined solve: the
/// inner correction system is only the f64 system to f32 storage rounding
/// (≈1e-6 operator-relative), so solving it much past 1e-5 buys nothing —
/// the outer f64 residual correction supplies the remaining accuracy, one
/// geometric contraction per sweep.
const REFINE_INNER_TOL: f64 = 1e-5;

/// Refinement sweep cap: each sweep contracts the outer residual by
/// roughly `REFINE_INNER_TOL + κ·ε₃₂`, so realistic solves converge in
/// 1–4 sweeps; the cap (with the stagnation guard) bounds pathological
/// systems.
const REFINE_MAX_SWEEPS: u64 = 16;

/// Builder for [`Session`].
#[derive(Clone, Copy, Debug)]
pub struct SessionBuilder {
    threads: usize,
    backend: Backend,
    registry_capacity: usize,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            threads: 0,
            backend: Backend::Auto,
            registry_capacity: DEFAULT_REGISTRY_CAPACITY,
        }
    }
}

impl SessionBuilder {
    /// Worker threads for the native phases (0 ⇒ all available cores).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Near-field backend selection (default [`Backend::Auto`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Operator-registry LRU capacity (default 64, min 1).
    pub fn registry_capacity(mut self, capacity: usize) -> Self {
        self.registry_capacity = capacity;
        self
    }

    /// Build the session (probes PJRT artifacts unless backend is Native).
    pub fn build(self) -> Session {
        Session {
            core: Arc::new(SessionCore {
                coord: Coordinator::new(CoordinatorConfig {
                    threads: self.threads,
                    backend: self.backend,
                }),
                registry: Registry::new(self.registry_capacity),
                tune_cache: Mutex::new(HashMap::new()),
                counters: CounterCells::default(),
            }),
        }
    }
}

/// A long-lived service context: coordinator + operator registry +
/// tolerance-resolution cache. See the module docs for the request model.
///
/// `Session` is a thin owner of an [`Arc<SessionCore>`](SessionCore): every
/// verb takes `&self` and delegates to the core, and
/// [`Session::clone_core`] hands that same core to other threads — the
/// serving layer's connection handlers and micro-batch workers — which
/// then share one registry, one tune cache, and one set of counters.
pub struct Session {
    core: Arc<SessionCore>,
}

/// The shareable heart of a [`Session`]. Every field is either immutable
/// after construction or internally synchronized — the sharded registry
/// and the coordinator take `&self`, the tune cache sits behind a mutex,
/// the per-verb counters are atomics — so the core is `Send + Sync` and
/// all four request verbs work through a shared reference. This is what
/// lets one hot operator serve MVMs from many threads at once.
pub struct SessionCore {
    coord: Coordinator,
    registry: Registry,
    tune_cache: Mutex<HashMap<TuneKey, Resolved>>,
    counters: CounterCells,
}

/// Cumulative per-verb call counters. These are the session's observable
/// request log: consumers assert efficiency invariants against them (e.g.
/// "repeated GP predictions trigger zero additional solves", "one training
/// iteration issues at most two batched solves") without instrumenting the
/// operators themselves. Internal MVMs performed *inside* a solve are not
/// double-counted as `mvm` calls.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionCounters {
    /// [`Session::mvm`] calls.
    pub mvm: u64,
    /// [`Session::mvm_batch`] calls.
    pub mvm_batch: u64,
    /// [`Session::solve`] calls.
    pub solve: u64,
    /// [`Session::solve_batch`] calls.
    pub solve_batch: u64,
    /// Mixed-precision refinement sweeps across all refined solves: one
    /// sweep = one inner CG run against the f32-tier operator plus one
    /// outer full-precision residual correction. Solves against f64-tier
    /// operators contribute zero.
    pub refine_sweeps: u64,
}

/// Interior-mutable cells behind [`SessionCounters`]: plain atomics, so
/// concurrent serving threads bump them through `&self` without a lock
/// and `counters()` stays readable mid-serve.
#[derive(Default)]
struct CounterCells {
    mvm: AtomicU64,
    mvm_batch: AtomicU64,
    solve: AtomicU64,
    solve_batch: AtomicU64,
    refine_sweeps: AtomicU64,
}

impl CounterCells {
    fn snapshot(&self) -> SessionCounters {
        SessionCounters {
            mvm: self.mvm.load(Ordering::Relaxed),
            mvm_batch: self.mvm_batch.load(Ordering::Relaxed),
            solve: self.solve.load(Ordering::Relaxed),
            solve_batch: self.solve_batch.load(Ordering::Relaxed),
            refine_sweeps: self.refine_sweeps.load(Ordering::Relaxed),
        }
    }
}

/// Identity of one tolerance resolution: kernel × dimension × ε × the
/// scaled dataset diameter the bound was maximized over (bit patterns, so
/// caching is exact).
type TuneKey = (Family, u64, usize, u64, u64);

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Native-only session (no PJRT artifact probe) — the common
    /// bench/test configuration.
    pub fn native(threads: usize) -> Session {
        Session::builder().threads(threads).backend(Backend::Native).build()
    }

    /// Wrap an already-shared core in the ergonomic `Session` surface —
    /// the inverse of [`Session::clone_core`].
    pub fn from_core(core: Arc<SessionCore>) -> Session {
        Session { core }
    }

    /// Borrow the shared core.
    pub fn core(&self) -> &Arc<SessionCore> {
        &self.core
    }

    /// Clone the shared core for another thread. Handles built through
    /// either surface hit the same registry; counters and metrics
    /// aggregate across all holders.
    pub fn clone_core(&self) -> Arc<SessionCore> {
        Arc::clone(&self.core)
    }

    /// Begin an operator request over `sources` (see [`OpSpec`]).
    pub fn operator<'a>(&'a self, sources: &'a Points) -> OpSpec<'a> {
        self.core.operator(sources)
    }

    /// Begin an additive (ANOVA) composite-operator request over `sources`
    /// (see [`AdditiveSpec`]): a weighted sum of registry-cached FKT terms,
    /// each over a low-dimensional coordinate projection.
    pub fn additive<'a>(&'a self, sources: &'a Points) -> AdditiveSpec<'a> {
        self.core.additive(sources)
    }

    /// Single-RHS product `z = K · w` through the configured backend.
    pub fn mvm(&self, op: &OpHandle, w: &[f64]) -> Vec<f64> {
        self.core.mvm(op, w)
    }

    /// Batched multi-RHS product over `m` column-major columns
    /// (`w[c*n..(c+1)*n]` is column c) — fused backends share one
    /// traversal across all columns.
    pub fn mvm_batch(&self, op: &OpHandle, w: &[f64], m: usize) -> Vec<f64> {
        self.core.mvm_batch(op, w, m)
    }

    /// First-class linear solve: `(K + diag(noise) + jitter·I) x = y` by
    /// (optionally block-Jacobi preconditioned) conjugate gradients over
    /// session MVMs. This is the GP representer-weight system of paper
    /// §5.3 promoted to a session verb — any consumer with a square
    /// operator can invert it without knowing about CG or preconditioners.
    pub fn solve(&self, op: &OpHandle, y: &[f64], opts: &SolveOpts) -> CgResult {
        self.core.solve(op, y, opts)
    }

    /// Batched first-class solve: `m` column-major right-hand sides in ONE
    /// lockstep block-CG run — see [`SessionCore::solve_batch`].
    pub fn solve_batch(
        &self,
        op: &OpHandle,
        y: &[f64],
        m: usize,
        opts: &SolveOpts,
    ) -> BatchCgResult {
        self.core.solve_batch(op, y, m, opts)
    }

    /// Cumulative per-verb call counters (see [`SessionCounters`]).
    pub fn counters(&self) -> SessionCounters {
        self.core.counters()
    }

    /// Metrics of the most recent `mvm`/`mvm_batch` (solves record their
    /// last internal MVM).
    pub fn last_metrics(&self) -> MvmMetrics {
        self.core.last_metrics()
    }

    /// Cumulative stats of the session's shared worker pool (all zeros on
    /// a single-threaded session, which owns no pool).
    pub fn pool_stats(&self) -> PoolStats {
        self.core.pool_stats()
    }

    /// Operator-registry counters (hits, misses, coalesced builds,
    /// evictions, build time).
    pub fn registry_stats(&self) -> RegistryStats {
        self.core.registry_stats()
    }

    /// Drop all cached operators (counters survive).
    pub fn clear_registry(&self) {
        self.core.clear_registry()
    }

    /// Effective worker-thread count.
    pub fn threads(&self) -> usize {
        self.core.threads()
    }

    /// Whether the PJRT tile path would be used for this kernel family.
    pub fn will_use_pjrt(&self, family: &str, dim: usize) -> bool {
        self.core.will_use_pjrt(family, dim)
    }
}

impl SessionCore {
    /// Begin an operator request over `sources` (see [`OpSpec`]) against
    /// this shared core. Identical to [`Session::operator`], available
    /// wherever only the `Arc<SessionCore>` travels (batcher workers,
    /// connection threads).
    pub fn operator<'a>(&'a self, sources: &'a Points) -> OpSpec<'a> {
        OpSpec {
            session: self,
            sources,
            targets: None,
            kernel: Kernel::canonical(Family::Gaussian),
            cfg: FktConfig::default(),
            tolerance: None,
            p_override: None,
            theta_override: None,
            panel_budget: None,
            precision: None,
            dense: false,
            transient: false,
        }
    }

    /// [`Session::additive`] on the shared core (see [`AdditiveSpec`]).
    pub fn additive<'a>(&'a self, sources: &'a Points) -> AdditiveSpec<'a> {
        AdditiveSpec {
            session: self,
            sources,
            targets: None,
            kernel: Kernel::canonical(Family::Gaussian),
            cfg: FktConfig::default(),
            tolerance: None,
            precision: None,
            subsets: None,
            weights: None,
            seed: 0x5eed,
        }
    }

    /// [`Session::mvm`] on the shared core.
    pub fn mvm(&self, op: &OpHandle, w: &[f64]) -> Vec<f64> {
        self.counters.mvm.fetch_add(1, Ordering::Relaxed);
        self.coord.mvm(op.op.as_ref(), w)
    }

    /// [`Session::mvm_batch`] on the shared core.
    pub fn mvm_batch(&self, op: &OpHandle, w: &[f64], m: usize) -> Vec<f64> {
        self.counters.mvm_batch.fetch_add(1, Ordering::Relaxed);
        self.coord.mvm_batch(op.op.as_ref(), w, m)
    }

    /// [`Session::solve`] on the shared core.
    pub fn solve(&self, op: &OpHandle, y: &[f64], opts: &SolveOpts) -> CgResult {
        // Equal counts are not enough — a rectangular operator over 500
        // sources and 500 *different* targets is not symmetric, and CG on
        // it would silently return garbage.
        assert!(
            op.is_square(),
            "solve needs a square operator (built without .targets(..))"
        );
        assert_eq!(y.len(), op.num_sources(), "right-hand side length mismatch");
        self.counters.solve.fetch_add(1, Ordering::Relaxed);
        let zeros;
        let noise: &[f64] = match opts.noise {
            Some(n) => {
                assert_eq!(n.len(), y.len(), "noise diagonal length mismatch");
                n
            }
            None => {
                zeros = vec![0.0; y.len()];
                &zeros
            }
        };
        // f32-tier operators route through mixed-precision iterative
        // refinement: inner CG rides the fast f32 panels, the outer loop
        // corrects against the full-precision residual, so the returned
        // residual is honest w.r.t. the f64 operator.
        if op.precision().is_f32() && op.as_fkt().is_some() {
            return self.solve_refined(op, y, noise, opts);
        }
        let jitter = opts.jitter;
        let coord = &self.coord;
        let kernel_op = op.op.as_ref();
        let mut apply = |v: &[f64]| -> Vec<f64> {
            let mut kv = coord.mvm(kernel_op, v);
            for i in 0..v.len() {
                kv[i] += (noise[i] + jitter) * v[i];
            }
            kv
        };
        let budget = CgBudget { max_iters: opts.max_iters, deadline: opts.deadline };
        if opts.precondition {
            if let Some(fkt) = op.as_fkt() {
                let pre = BlockJacobi::build(fkt, noise, jitter);
                let mut precond = |r: &[f64]| pre.apply(r);
                return preconditioned_cg_budgeted(&mut apply, &mut precond, y, opts.tol, &budget);
            }
        }
        let mut identity = |r: &[f64]| r.to_vec();
        preconditioned_cg_budgeted(&mut apply, &mut identity, y, opts.tol, &budget)
    }

    /// Batched first-class solve: `(K + diag(noise) + jitter·I) X = Y` for
    /// `m` column-major right-hand sides in ONE lockstep block-CG run.
    /// Every CG iteration costs a single [`Session::mvm_batch`]-style fused
    /// traversal for all columns, and the leaf-block Jacobi preconditioner
    /// is factorized ONCE and reused across every column and iteration —
    /// this is what makes Hutchinson-probe workloads (GP hyperparameter
    /// training solves `[y, z₁ … z_P]` together) cost barely more than a
    /// single solve. Column `c` of the result matches `solve` on column `c`
    /// to round-off.
    pub fn solve_batch(
        &self,
        op: &OpHandle,
        y: &[f64],
        m: usize,
        opts: &SolveOpts,
    ) -> BatchCgResult {
        assert!(
            op.is_square(),
            "solve_batch needs a square operator (built without .targets(..))"
        );
        assert!(m > 0, "solve_batch needs at least one column");
        let n = op.num_sources();
        assert_eq!(y.len(), n * m, "right-hand side block shape mismatch");
        self.counters.solve_batch.fetch_add(1, Ordering::Relaxed);
        let zeros;
        let noise: &[f64] = match opts.noise {
            Some(nz) => {
                assert_eq!(nz.len(), n, "noise diagonal length mismatch");
                nz
            }
            None => {
                zeros = vec![0.0; n];
                &zeros
            }
        };
        if op.precision().is_f32() && op.as_fkt().is_some() {
            return self.solve_refined_batch(op, y, m, noise, opts);
        }
        let jitter = opts.jitter;
        let coord = &self.coord;
        let kernel_op = op.op.as_ref();
        let mut apply = |v: &[f64]| -> Vec<f64> {
            let mut kv = coord.mvm_batch(kernel_op, v, m);
            for c in 0..m {
                for i in 0..n {
                    kv[c * n + i] += (noise[i] + jitter) * v[c * n + i];
                }
            }
            kv
        };
        let budget = CgBudget { max_iters: opts.max_iters, deadline: opts.deadline };
        if opts.precondition {
            if let Some(fkt) = op.as_fkt() {
                // One factorization, every column, every iteration.
                let pre = BlockJacobi::build(fkt, noise, jitter);
                let mut precond = |r: &[f64]| pre.apply_batch(r, m);
                return preconditioned_cg_batch_budgeted(
                    &mut apply,
                    &mut precond,
                    y,
                    m,
                    opts.tol,
                    &budget,
                );
            }
        }
        let mut identity = |r: &[f64]| r.to_vec();
        preconditioned_cg_batch_budgeted(&mut apply, &mut identity, y, m, opts.tol, &budget)
    }

    /// Mixed-precision iterative refinement behind [`Session::solve`] for
    /// f32-tier operators. Each sweep solves the *correction* system
    /// `(K₃₂ + D) d = r` by preconditioned CG against the fast f32 panels
    /// (to [`REFINE_INNER_TOL`], no tighter — the f32 system only agrees
    /// with the f64 one to storage rounding, so over-solving it is wasted
    /// work), then recomputes the residual `r = y − (K₆₄ + D) x` through
    /// the operator's full-precision streaming path. The loop ends when
    /// that f64 residual meets `opts.tol` — the same promise a pure-f64
    /// solve makes — or when a sweep stops halving it (the f32 error
    /// floor, reported honestly via `converged = false`). Sweeps
    /// accumulate in [`SessionCounters::refine_sweeps`].
    fn solve_refined(
        &self,
        op: &OpHandle,
        y: &[f64],
        noise: &[f64],
        opts: &SolveOpts,
    ) -> CgResult {
        let fkt = op.as_fkt().expect("refined solve requires an FKT operator");
        let threads = self.coord.threads();
        let n = y.len();
        let jitter = opts.jitter;
        let bnorm = vecops::norm2(y);
        if bnorm == 0.0 {
            return CgResult { x: vec![0.0; n], iterations: 0, rel_residual: 0.0, converged: true };
        }
        // One factorization serves every sweep (the leaf blocks depend on
        // the kernel and noise, not on the storage tier).
        let pre = if opts.precondition {
            Some(BlockJacobi::build(fkt, noise, jitter))
        } else {
            None
        };
        let inner_tol = opts.tol.max(REFINE_INNER_TOL);
        let mut x = vec![0.0; n];
        let mut r = y.to_vec();
        let mut rel = 1.0f64;
        let mut prev_rel = f64::INFINITY;
        let mut total_iters = 0usize;
        let mut sweeps = 0u64;
        let mut stalled = 0u32;
        let mut converged = false;
        while sweeps < REFINE_MAX_SWEEPS && total_iters < opts.max_iters {
            // Deadline pressure ends the refinement between sweeps; the
            // result carries the last honest f64 residual.
            if opts.deadline.is_some_and(|d| Instant::now() >= d) {
                break;
            }
            let inner = {
                let coord = &self.coord;
                let kernel_op = op.op.as_ref();
                let mut apply = |v: &[f64]| -> Vec<f64> {
                    let mut kv = coord.mvm(kernel_op, v);
                    for i in 0..n {
                        kv[i] += (noise[i] + jitter) * v[i];
                    }
                    kv
                };
                let budget =
                    CgBudget { max_iters: opts.max_iters - total_iters, deadline: opts.deadline };
                match &pre {
                    Some(p) => {
                        let mut precond = |rr: &[f64]| p.apply(rr);
                        preconditioned_cg_budgeted(&mut apply, &mut precond, &r, inner_tol, &budget)
                    }
                    None => {
                        let mut id = |rr: &[f64]| rr.to_vec();
                        preconditioned_cg_budgeted(&mut apply, &mut id, &r, inner_tol, &budget)
                    }
                }
            };
            vecops::axpy(1.0, &inner.x, &mut x);
            total_iters += inner.iterations.max(1);
            sweeps += 1;
            // Outer correction: the f64 residual, f32 panels bypassed.
            let mut kv = fkt.matvec_full_precision(&x, threads);
            for i in 0..n {
                kv[i] += (noise[i] + jitter) * x[i];
            }
            for i in 0..n {
                r[i] = y[i] - kv[i];
            }
            rel = vecops::norm2(&r) / bnorm;
            if rel <= opts.tol {
                converged = true;
                break;
            }
            // Stagnation at the f32 error floor: two CONSECUTIVE sweeps
            // that fail to halve the residual — one slow sweep is still
            // geometric progress on an ill-conditioned system.
            if rel >= 0.5 * prev_rel {
                stalled += 1;
                if stalled >= 2 {
                    break;
                }
            } else {
                stalled = 0;
            }
            prev_rel = rel;
        }
        self.counters.refine_sweeps.fetch_add(sweeps, Ordering::Relaxed);
        CgResult { x, iterations: total_iters, rel_residual: rel, converged }
    }

    /// Batched mixed-precision refinement behind [`Session::solve_batch`]
    /// (see [`Session::solve_refined`]): each sweep is ONE lockstep inner
    /// block-CG against the f32 operator plus ONE full-precision batched
    /// residual correction, so the whole batch pays one fused traversal
    /// per inner iteration and one per sweep. Columns freeze as their f64
    /// residual meets `opts.tol` (their residual block is zeroed, so the
    /// inner CG skips them); column `c` reports its own inner-iteration
    /// total and outer residual.
    fn solve_refined_batch(
        &self,
        op: &OpHandle,
        y: &[f64],
        m: usize,
        noise: &[f64],
        opts: &SolveOpts,
    ) -> BatchCgResult {
        let fkt = op.as_fkt().expect("refined solve requires an FKT operator");
        let threads = self.coord.threads();
        let n = y.len() / m;
        let jitter = opts.jitter;
        let col = |c: usize| c * n..(c + 1) * n;
        let mut bnorm = vec![0.0; m];
        let mut converged = vec![false; m];
        let mut rel_residual = vec![0.0; m];
        let mut x = vec![0.0; n * m];
        let mut r = y.to_vec();
        for c in 0..m {
            bnorm[c] = vecops::norm2(&y[col(c)]);
            if bnorm[c] == 0.0 {
                converged[c] = true;
                r[col(c)].fill(0.0);
            }
        }
        let mut iterations = vec![0usize; m];
        let mut batched_mvms = 0usize;
        if converged.iter().all(|&c| c) {
            return BatchCgResult { x, iterations, rel_residual, converged, batched_mvms };
        }
        let pre = if opts.precondition {
            Some(BlockJacobi::build(fkt, noise, jitter))
        } else {
            None
        };
        let inner_tol = opts.tol.max(REFINE_INNER_TOL);
        let mut sweeps = 0u64;
        let mut stalled = 0u32;
        let mut prev_worst = f64::INFINITY;
        while sweeps < REFINE_MAX_SWEEPS {
            let spent = *iterations.iter().max().expect("m > 0");
            if spent >= opts.max_iters {
                break;
            }
            // Deadline pressure ends the refinement between sweeps; record
            // the honest residual of whatever iterate each column holds.
            if opts.deadline.is_some_and(|d| Instant::now() >= d) {
                for c in 0..m {
                    if !converged[c] {
                        rel_residual[c] = vecops::norm2(&r[col(c)]) / bnorm[c];
                    }
                }
                break;
            }
            let inner = {
                let coord = &self.coord;
                let kernel_op = op.op.as_ref();
                let mut apply = |v: &[f64]| -> Vec<f64> {
                    let mut kv = coord.mvm_batch(kernel_op, v, m);
                    for c in 0..m {
                        for i in 0..n {
                            kv[c * n + i] += (noise[i] + jitter) * v[c * n + i];
                        }
                    }
                    kv
                };
                let budget =
                    CgBudget { max_iters: opts.max_iters - spent, deadline: opts.deadline };
                match &pre {
                    Some(p) => {
                        let mut precond = |rr: &[f64]| p.apply_batch(rr, m);
                        preconditioned_cg_batch_budgeted(
                            &mut apply,
                            &mut precond,
                            &r,
                            m,
                            inner_tol,
                            &budget,
                        )
                    }
                    None => {
                        let mut identity = |rr: &[f64]| rr.to_vec();
                        preconditioned_cg_batch_budgeted(
                            &mut apply,
                            &mut identity,
                            &r,
                            m,
                            inner_tol,
                            &budget,
                        )
                    }
                }
            };
            vecops::axpy(1.0, &inner.x, &mut x);
            for c in 0..m {
                if !converged[c] {
                    iterations[c] += inner.iterations[c];
                }
            }
            batched_mvms += inner.batched_mvms;
            sweeps += 1;
            // Outer correction: batched f64 residual, f32 panels bypassed.
            let mut kv = fkt.matmat_full_precision(&x, m, threads);
            batched_mvms += 1;
            for c in 0..m {
                for i in 0..n {
                    kv[c * n + i] += (noise[i] + jitter) * x[c * n + i];
                }
            }
            let mut worst = 0.0f64;
            let mut all_done = true;
            for c in 0..m {
                if converged[c] {
                    r[col(c)].fill(0.0);
                    continue;
                }
                for i in 0..n {
                    r[c * n + i] = y[c * n + i] - kv[c * n + i];
                }
                let rel = vecops::norm2(&r[col(c)]) / bnorm[c];
                rel_residual[c] = rel;
                if rel <= opts.tol {
                    converged[c] = true;
                    r[col(c)].fill(0.0);
                } else {
                    worst = worst.max(rel);
                    all_done = false;
                }
            }
            if all_done {
                break;
            }
            // As in the single-RHS path: break only after two consecutive
            // sweeps fail to halve the worst unconverged residual.
            if worst >= 0.5 * prev_worst {
                stalled += 1;
                if stalled >= 2 {
                    break;
                }
            } else {
                stalled = 0;
            }
            prev_worst = worst;
        }
        self.counters.refine_sweeps.fetch_add(sweeps, Ordering::Relaxed);
        BatchCgResult { x, iterations, rel_residual, converged, batched_mvms }
    }

    /// Cumulative per-verb call counters: an atomic snapshot readable
    /// from any thread mid-serve (see [`SessionCounters`]).
    pub fn counters(&self) -> SessionCounters {
        self.counters.snapshot()
    }

    /// Metrics of the most recent `mvm`/`mvm_batch` (solves record their
    /// last internal MVM). Under concurrency: whichever request through
    /// this core finished last.
    pub fn last_metrics(&self) -> MvmMetrics {
        self.coord.last_metrics()
    }

    /// Cumulative stats of the core's shared worker pool (all zeros when
    /// `threads == 1`: the sequential path never creates a pool).
    pub fn pool_stats(&self) -> PoolStats {
        self.coord.pool_stats()
    }

    /// Per-apply metrics variant of [`SessionCore::mvm_batch`]: returns
    /// this request's own [`MvmMetrics`] snapshot alongside the result,
    /// so concurrent callers never read each other's numbers out of the
    /// shared last-metrics slot.
    pub fn mvm_batch_metered(&self, op: &OpHandle, w: &[f64], m: usize) -> (Vec<f64>, MvmMetrics) {
        self.counters.mvm_batch.fetch_add(1, Ordering::Relaxed);
        self.coord.mvm_batch_metered(op.op.as_ref(), w, m)
    }

    /// Operator-registry counters (hits, misses, coalesced builds,
    /// evictions, build time).
    pub fn registry_stats(&self) -> RegistryStats {
        self.registry.stats()
    }

    /// Drop all cached operators (counters survive).
    pub fn clear_registry(&self) {
        self.registry.clear()
    }

    /// Effective worker-thread count.
    pub fn threads(&self) -> usize {
        self.coord.threads()
    }

    /// Whether the PJRT tile path would be used for this kernel family.
    pub fn will_use_pjrt(&self, family: &str, dim: usize) -> bool {
        self.coord.will_use_pjrt(family, dim)
    }

    /// Resolve (and cache) a tolerance request. The cache is flushed when
    /// it reaches [`TUNE_CACHE_FLUSH`] entries — r_max is a bit-exact
    /// diameter, so a stream of distinct datasets would otherwise grow
    /// this map without bound while the operator registry stays flat.
    /// The mutex is dropped around the actual resolution, so two threads
    /// may redundantly resolve the same key (a cheap closed-form sweep,
    /// unlike an operator build) — last writer wins, both get equal
    /// values.
    fn resolve_cached(&self, kernel: &Kernel, d: usize, eps: f64, r_max: f64) -> Option<Resolved> {
        let key: TuneKey =
            (kernel.family, kernel.scale.to_bits(), d.max(2), eps.to_bits(), r_max.to_bits());
        if let Some(r) = lock(&self.tune_cache).get(&key) {
            return Some(*r);
        }
        let res = tune::resolve(kernel, d, eps, r_max)?;
        let mut cache = lock(&self.tune_cache);
        if cache.len() >= TUNE_CACHE_FLUSH {
            cache.clear();
        }
        cache.insert(key, res);
        Some(res)
    }
}

/// Scaled diameter of the request's geometry: the bounding-box diagonal
/// over sources ∪ targets, times the kernel's coordinate scale — the
/// largest radius the truncation bound needs to cover.
fn scaled_diameter(sources: &Points, targets: Option<&Points>, scale: f64) -> f64 {
    if sources.is_empty() {
        return 1.0;
    }
    let (mut lo, mut hi) = sources.bounding_box();
    if let Some(t) = targets {
        if !t.is_empty() {
            let (tlo, thi) = t.bounding_box();
            for a in 0..sources.d.min(t.d) {
                lo[a] = lo[a].min(tlo[a]);
                hi[a] = hi[a].max(thi[a]);
            }
        }
    }
    let mut acc = 0.0;
    for a in 0..lo.len() {
        let w = hi[a] - lo[a];
        acc += w * w;
    }
    acc.sqrt() * scale
}

/// One operator request, builder-style. Created by [`Session::operator`]
/// (or [`SessionCore::operator`] on a shared core); finished by
/// [`OpSpec::build`], which consults the registry (so equal requests over
/// equal data return pointer-equal cached operators — including requests
/// racing from different threads, which coalesce onto one build).
pub struct OpSpec<'a> {
    session: &'a SessionCore,
    sources: &'a Points,
    targets: Option<&'a Points>,
    kernel: Kernel,
    cfg: FktConfig,
    tolerance: Option<f64>,
    p_override: Option<usize>,
    theta_override: Option<f64>,
    panel_budget: Option<usize>,
    precision: Option<Precision>,
    dense: bool,
    transient: bool,
}

impl<'a> OpSpec<'a> {
    /// Rectangular operator `K(targets, sources)` (GP prediction shape);
    /// without this the operator is square (targets = sources).
    pub fn targets(mut self, targets: &'a Points) -> Self {
        self.targets = Some(targets);
        self
    }

    /// Canonical kernel of `family` (scale 1). Default: Gaussian.
    pub fn kernel(mut self, family: Family) -> Self {
        self.kernel = Kernel::canonical(family);
        self
    }

    /// Full kernel with an explicit coordinate scale / length-scale.
    pub fn scaled_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Wholesale FKT configuration (p, θ, leaf size, center, compression).
    /// `.tolerance()` and the per-field setters still override on top.
    pub fn config(mut self, cfg: FktConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Request accuracy ε: the session resolves the cheapest `(p, θ)`
    /// whose Lemma 4.1 truncation bound is ≤ ε for this kernel and this
    /// dataset's scaled diameter. Panics at [`OpSpec::build`] if ε is
    /// unattainable within the order cap — pass explicit `.order()` /
    /// `.theta()` instead for out-of-range demands.
    pub fn tolerance(mut self, eps: f64) -> Self {
        self.tolerance = Some(eps);
        self
    }

    /// Explicit truncation order p (overrides `.tolerance()`'s choice).
    pub fn order(mut self, p: usize) -> Self {
        self.p_override = Some(p);
        self
    }

    /// Explicit separation parameter θ (overrides `.tolerance()`'s choice).
    pub fn theta(mut self, theta: f64) -> Self {
        self.theta_override = Some(theta);
        self
    }

    /// Maximum points per leaf.
    pub fn leaf_capacity(mut self, leaf: usize) -> Self {
        self.cfg.leaf_capacity = leaf;
        self
    }

    /// Expansion-center convention.
    pub fn center(mut self, center: ExpansionCenter) -> Self {
        self.cfg.center = center;
        self
    }

    /// Toggle the §A.4 compressed radial representation.
    pub fn compression(mut self, on: bool) -> Self {
        self.cfg.compression = on;
        self
    }

    /// Byte budget for the operator's cached far-field evaluation panels
    /// (see `fkt::panels`): panels past the budget are recomputed on every
    /// apply (streaming fallback), and 0 forces pure streaming. Part of
    /// the registry key — requests that differ only in budget build
    /// distinct operators, since the budget changes the operator's memory
    /// footprint and apply-time behavior. Held apart from the wholesale
    /// `.config(..)` setter, so the two compose in either order.
    pub fn panel_budget(mut self, bytes: usize) -> Self {
        self.panel_budget = Some(bytes);
        self
    }

    /// Storage-precision tier of the apply path (default
    /// [`Precision::Auto`]): `F64`/`F32` pin the tier; `Auto` lets the
    /// tolerance resolver pick f32 storage when the requested ε leaves
    /// headroom above f32 round-off (ε ≥ [`F32_AUTO_MIN_EPS`] — see
    /// [`auto_precision`]) and keeps f64 otherwise, including when no
    /// tolerance was requested. The resolved tier joins the registry key:
    /// the same spec at f32 and f64 caches two distinct operators, while
    /// an `Auto` request shares its resolved tier's entry. An explicit
    /// call — including an explicit `Auto` — takes precedence over a tier
    /// carried in by the wholesale `.config(..)` setter regardless of
    /// builder-call order.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// The paper's Barnes–Hut baseline: p = 0, centroid centers.
    pub fn barnes_hut(mut self, theta: f64, leaf_capacity: usize) -> Self {
        self.cfg = FktConfig::barnes_hut(theta, leaf_capacity);
        self
    }

    /// Exact dense backend instead of the FKT (O(N·M) reference).
    pub fn dense(mut self) -> Self {
        self.dense = true;
        self
    }

    /// Build without touching the registry: no fingerprinting, no caching,
    /// no eviction pressure. The right mode for operators that can never
    /// be requested twice — t-SNE's per-iteration embedding operators —
    /// which would otherwise fill the LRU with dead entries and evict
    /// genuinely reusable ones.
    pub fn transient(mut self) -> Self {
        self.transient = true;
        self
    }

    /// Resolve the final configuration, consult the registry, and return a
    /// cheap cloneable handle to the (possibly cached) operator.
    pub fn build(self) -> OpHandle {
        let OpSpec {
            session,
            sources,
            targets,
            kernel,
            mut cfg,
            tolerance,
            p_override,
            theta_override,
            panel_budget,
            precision,
            dense,
            transient,
        } = self;
        let mut resolved = None;
        if dense {
            // DenseOperator ignores every FKT hyperparameter; canonicalize
            // them so semantically identical dense requests share one
            // registry key regardless of stray .config()/.order() calls.
            // (It computes in f64 — precision canonicalizes with the rest.)
            cfg = FktConfig { precision: Precision::F64, ..FktConfig::default() };
        } else {
            // Storage tier, resolved before keying so `Auto` never reaches
            // the registry: an explicit `.precision(..)` call wins (even an
            // explicit `Auto` — it re-engages the rule over a tier pinned
            // by `.config(..)`), else the config-carried tier, else Auto.
            let requested = precision.unwrap_or(cfg.precision);
            cfg.precision = match requested {
                Precision::Auto => tune::auto_precision(tolerance),
                pinned => pinned,
            };
            // Resolution is skipped when both hyperparameters are forced
            // (nothing left to resolve — and a forced config must not
            // panic on an unattainable ε it will ignore anyway).
            let fully_forced = p_override.is_some() && theta_override.is_some();
            if let Some(eps) = tolerance {
                if !fully_forced {
                    let r_max = scaled_diameter(sources, targets, kernel.scale);
                    let res = session
                        .resolve_cached(&kernel, sources.d, eps, r_max)
                        .unwrap_or_else(|| {
                            panic!(
                                "tolerance {eps:.1e} unattainable for {:?} (d={}, scaled \
                                 diameter {r_max:.2}); pass explicit .order(p)/.theta(t)",
                                kernel.family, sources.d
                            )
                        });
                    cfg.p = res.p;
                    cfg.theta = res.theta;
                    resolved = Some(res);
                }
            }
            if let Some(p) = p_override {
                cfg.p = p;
            }
            if let Some(t) = theta_override {
                cfg.theta = t;
            }
            // An override invalidates the resolution's (p, θ, bound) as a
            // description of the operator actually built — don't let the
            // handle report hyperparameters it doesn't have.
            if p_override.is_some() || theta_override.is_some() {
                resolved = None;
            }
            // The budget overrides whatever `.config(..)` carried,
            // regardless of builder-call order.
            if let Some(bytes) = panel_budget {
                cfg.panel_budget_bytes = bytes;
            }
        }
        let build_op = || -> Arc<dyn KernelOp + Send + Sync> {
            if dense {
                Arc::new(DenseOperator::new(sources, targets, kernel))
            } else {
                Arc::new(FktOperator::new_exec(sources, targets, kernel, cfg, session.coord.exec()))
            }
        };
        let square = targets.is_none();
        if transient {
            return OpHandle { op: build_op(), kernel, cfg, dense, square, resolved };
        }
        let key = OpKey {
            src_fp: fingerprint(sources),
            tgt_fp: targets.map(fingerprint),
            family: kernel.family,
            scale_bits: kernel.scale.to_bits(),
            p: cfg.p,
            theta_bits: cfg.theta.to_bits(),
            leaf_capacity: cfg.leaf_capacity,
            center: cfg.center,
            compression: cfg.compression,
            panel_budget: cfg.panel_budget_bytes,
            precision: cfg.precision,
            dense,
            composite: false,
        };
        let op = session.registry.get_or_build(key, build_op);
        OpHandle { op, kernel, cfg, dense, square, resolved }
    }
}

/// Feature-subset selection for an additive (ANOVA) operator request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Subsets {
    /// `k` subsets of `arity` distinct axes each, sampled deterministically
    /// from the spec's seed (duplicate subsets are rejected while
    /// possible).
    Random {
        /// Number of subsets (terms).
        k: usize,
        /// Axes per subset.
        arity: usize,
    },
    /// Explicit axis lists; each subset is canonicalized (sorted, deduped)
    /// so `[2, 0]` and `[0, 2]` name the same term.
    Explicit(Vec<Vec<usize>>),
}

impl Subsets {
    /// Parse the CLI/serve spelling: `random:KxA` (e.g. `random:8x3`) or
    /// explicit `;`-separated comma lists (e.g. `0,1,2;3,4,5`).
    pub fn parse(text: &str) -> Result<Subsets, String> {
        if let Some(spec) = text.strip_prefix("random:") {
            let (k, arity) = spec
                .split_once('x')
                .ok_or_else(|| format!("expected random:KxA, got {text:?}"))?;
            let k = k.trim().parse::<usize>().map_err(|e| format!("bad subset count: {e}"))?;
            let arity =
                arity.trim().parse::<usize>().map_err(|e| format!("bad subset arity: {e}"))?;
            return Ok(Subsets::Random { k, arity });
        }
        let mut subsets = Vec::new();
        for group in text.split(';') {
            let group = group.trim();
            if group.is_empty() {
                continue;
            }
            let axes: Result<Vec<usize>, _> =
                group.split(',').map(|a| a.trim().parse::<usize>()).collect();
            subsets.push(axes.map_err(|e| format!("bad axis in {group:?}: {e}"))?);
        }
        if subsets.is_empty() {
            return Err(format!("no subsets in {text:?}"));
        }
        Ok(Subsets::Explicit(subsets))
    }

    /// Resolve to concrete sorted axis lists for a `d`-dimensional dataset.
    /// Deterministic in `(self, d, seed)`.
    pub fn materialize(&self, d: usize, seed: u64) -> Result<Vec<Vec<usize>>, String> {
        match self {
            Subsets::Random { k, arity } => {
                if *k == 0 {
                    return Err("need at least one subset".into());
                }
                if *arity == 0 || *arity > d {
                    return Err(format!("subset arity {arity} out of range for d={d}"));
                }
                let mut rng = Pcg32::seeded(seed);
                let mut out: Vec<Vec<usize>> = Vec::with_capacity(*k);
                let mut attempts = 0usize;
                while out.len() < *k {
                    // Sort-of Floyd sampling: draw without replacement by
                    // rejection inside one subset (arity ≤ d keeps this
                    // cheap), then canonicalize.
                    let mut subset: Vec<usize> = Vec::with_capacity(*arity);
                    while subset.len() < *arity {
                        let a = rng.below(d);
                        if !subset.contains(&a) {
                            subset.push(a);
                        }
                    }
                    subset.sort_unstable();
                    attempts += 1;
                    // Prefer distinct subsets; past the retry budget (tiny
                    // axis spaces) duplicates are admitted — the algebra is
                    // a multiset.
                    if out.contains(&subset) && attempts < k * 20 {
                        continue;
                    }
                    out.push(subset);
                }
                Ok(out)
            }
            Subsets::Explicit(subsets) => {
                if subsets.is_empty() {
                    return Err("need at least one subset".into());
                }
                let mut out = Vec::with_capacity(subsets.len());
                for s in subsets {
                    if s.is_empty() {
                        return Err("empty subset".into());
                    }
                    let mut s = s.clone();
                    s.sort_unstable();
                    s.dedup();
                    if let Some(&bad) = s.iter().find(|&&a| a >= d) {
                        return Err(format!("axis {bad} out of range for d={d}"));
                    }
                    out.push(s);
                }
                Ok(out)
            }
        }
    }
}

/// One additive (ANOVA) composite-operator request, builder-style:
/// `K = Σ_t w_t · K(x_{S_t}, y_{S_t})` over feature subsets `S_t`
/// (Nestler–Stoll–Wagner, arXiv:2111.10140). Created by
/// [`Session::additive`]; finished by [`AdditiveSpec::build`].
///
/// Every term is an ordinary registry-cached FKT operator over a
/// coordinate projection, keyed by
/// [`projection_fingerprint`](registry::projection_fingerprint) — so two
/// composites sharing a subset share that term's Arc through the registry
/// — and the composite itself is cached under the *multiset* of its
/// weighted term keys
/// ([`composite_fingerprint`](registry::composite_fingerprint)).
///
/// `.tolerance(ε)` splits uniformly across the `T` terms
/// ([`split_tolerance`]): each term resolves its own `(p, θ)` against its
/// *projected* dimension and diameter through the Lemma 4.1 resolver, so
/// a d=20 request stays feasible as long as every subset is low-arity.
pub struct AdditiveSpec<'a> {
    session: &'a SessionCore,
    sources: &'a Points,
    targets: Option<&'a Points>,
    kernel: Kernel,
    cfg: FktConfig,
    tolerance: Option<f64>,
    precision: Option<Precision>,
    subsets: Option<Subsets>,
    weights: Option<Vec<f64>>,
    seed: u64,
}

impl<'a> AdditiveSpec<'a> {
    /// Rectangular composite `K(targets, sources)` (GP prediction shape);
    /// without this the composite is square (targets = sources).
    pub fn targets(mut self, targets: &'a Points) -> Self {
        self.targets = Some(targets);
        self
    }

    /// Canonical kernel of `family` (scale 1) for every term. Default:
    /// Gaussian.
    pub fn kernel(mut self, family: Family) -> Self {
        self.kernel = Kernel::canonical(family);
        self
    }

    /// Full kernel with an explicit coordinate scale / length-scale,
    /// shared by every term.
    pub fn scaled_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Wholesale FKT configuration for the terms. Without `.tolerance()`,
    /// every term is built at exactly this `(p, θ)` — the frozen-config
    /// mode GP training uses.
    pub fn config(mut self, cfg: FktConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Requested aggregate accuracy ε, split uniformly across terms (see
    /// [`split_tolerance`]); each term resolves its own `(p, θ)` at ε/T
    /// against its projected dimension. Panics at [`AdditiveSpec::build`]
    /// when some term's share is unattainable within that dimension's
    /// order cap.
    pub fn tolerance(mut self, eps: f64) -> Self {
        self.tolerance = Some(eps);
        self
    }

    /// Storage-precision tier for every term (same `Auto` rule as
    /// [`OpSpec::precision`]).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Maximum points per leaf for every term.
    pub fn leaf_capacity(mut self, leaf: usize) -> Self {
        self.cfg.leaf_capacity = leaf;
        self
    }

    /// Feature subsets — required.
    pub fn subsets(mut self, subsets: Subsets) -> Self {
        self.subsets = Some(subsets);
        self
    }

    /// Per-term weights (default: all 1). Length must match the number of
    /// materialized subsets.
    pub fn weights(mut self, weights: Vec<f64>) -> Self {
        self.weights = Some(weights);
        self
    }

    /// Seed for `Subsets::Random` materialization (default `0x5eed`).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Materialize the subsets this spec would use without building
    /// anything — the CLI and GP layers use this to report/persist the
    /// actual axis lists behind a `Random` request.
    pub fn materialized_subsets(&self) -> Vec<Vec<usize>> {
        let subsets = self.subsets.as_ref().expect("additive request needs .subsets(..)");
        subsets
            .materialize(self.sources.d, self.seed)
            .unwrap_or_else(|e| panic!("invalid subsets: {e}"))
    }

    /// Resolve per-term configurations, consult the registry (terms first,
    /// then the composite under its multiset key), and return a handle to
    /// the (possibly cached) composite. A cached composite skips the term
    /// builds entirely; a cold composite still reuses any cached terms.
    pub fn build(self) -> OpHandle {
        let subs = self.materialized_subsets();
        let AdditiveSpec {
            session,
            sources,
            targets,
            kernel,
            mut cfg,
            tolerance,
            precision,
            subsets: _,
            weights,
            seed: _,
        } = self;
        let nterms = subs.len();
        let weights = weights.unwrap_or_else(|| vec![1.0; nterms]);
        assert_eq!(weights.len(), nterms, "one weight per subset");
        // Same precision rule as OpSpec: explicit call wins, then the
        // config-carried tier, and Auto resolves against the *aggregate*
        // tolerance (the f32 floor argument is about ε headroom, which the
        // split only tightens per term, never loosens in aggregate).
        let requested = precision.unwrap_or(cfg.precision);
        cfg.precision = match requested {
            Precision::Auto => tune::auto_precision(tolerance),
            pinned => pinned,
        };
        // Projected diameters come from the parent bounding box — O(d),
        // no projection materialized outside the build closures.
        let bbox = if sources.is_empty() {
            None
        } else {
            let (mut lo, mut hi) = sources.bounding_box();
            if let Some(t) = targets {
                if !t.is_empty() {
                    let (tlo, thi) = t.bounding_box();
                    for a in 0..sources.d.min(t.d) {
                        lo[a] = lo[a].min(tlo[a]);
                        hi[a] = hi[a].max(thi[a]);
                    }
                }
            }
            Some((lo, hi))
        };
        let src_fp = fingerprint(sources);
        let tgt_fp = targets.map(fingerprint);
        // Per-term (p, θ): ε/T through the Lemma 4.1 resolver at the
        // term's own (low) dimension, or the frozen config as-is.
        let mut term_keys: Vec<OpKey> = Vec::with_capacity(nterms);
        let mut term_cfgs: Vec<FktConfig> = Vec::with_capacity(nterms);
        for subset in &subs {
            let mut tcfg = cfg;
            if let Some(eps) = tolerance {
                let eps_t = tune::split_tolerance(eps, nterms);
                let r_max = match &bbox {
                    Some((lo, hi)) => {
                        let mut acc = 0.0;
                        for &a in subset {
                            let w = hi[a] - lo[a];
                            acc += w * w;
                        }
                        acc.sqrt() * kernel.scale
                    }
                    None => 1.0,
                };
                let res = session
                    .resolve_cached(&kernel, subset.len(), eps_t, r_max)
                    .unwrap_or_else(|| {
                        panic!(
                            "per-term tolerance {eps_t:.1e} (= {eps:.1e}/{nterms}) \
                             unattainable for {:?} on subset {subset:?} (arity {}, scaled \
                             diameter {r_max:.2}); use fewer/lower-arity subsets or a \
                             frozen .config(..)",
                            kernel.family,
                            subset.len()
                        )
                    });
                tcfg.p = res.p;
                tcfg.theta = res.theta;
            }
            term_keys.push(OpKey {
                src_fp: projection_fingerprint(src_fp, subset),
                tgt_fp: tgt_fp.map(|fp| projection_fingerprint(fp, subset)),
                family: kernel.family,
                scale_bits: kernel.scale.to_bits(),
                p: tcfg.p,
                theta_bits: tcfg.theta.to_bits(),
                leaf_capacity: tcfg.leaf_capacity,
                center: tcfg.center,
                compression: tcfg.compression,
                panel_budget: tcfg.panel_budget_bytes,
                precision: tcfg.precision,
                dense: false,
                composite: false,
            });
            term_cfgs.push(tcfg);
        }
        // The handle reports the conservative envelope of its terms, so a
        // frozen rebuild from `handle.config()` (GP training) is at least
        // as accurate as what the tolerance resolved.
        cfg.p = term_cfgs.iter().map(|c| c.p).max().expect("at least one term");
        cfg.theta = term_cfgs.iter().map(|c| c.theta).fold(f64::INFINITY, f64::min);
        let weighted_keys: Vec<(f64, OpKey)> =
            weights.iter().copied().zip(term_keys.iter().copied()).collect();
        let composite_key = OpKey {
            src_fp: composite_fingerprint(&weighted_keys),
            tgt_fp: None,
            family: kernel.family,
            scale_bits: kernel.scale.to_bits(),
            p: 0,
            theta_bits: 0,
            leaf_capacity: cfg.leaf_capacity,
            center: cfg.center,
            compression: cfg.compression,
            panel_budget: cfg.panel_budget_bytes,
            precision: cfg.precision,
            dense: false,
            composite: true,
        };
        // Terms build through nested registry lookups inside the composite
        // build closure — safe because builds run with no shard lock held,
        // and exactly what makes overlapping subsets across two composites
        // share one term Arc. The composite holds its own term Arcs, so
        // registry eviction of a sub-term never breaks a live composite.
        let op = session.registry.get_or_build(composite_key, || {
            let terms: Vec<(f64, SharedTermOp)> = subs
                .iter()
                .zip(&term_keys)
                .zip(&term_cfgs)
                .zip(&weights)
                .map(|(((subset, key), tcfg), &w)| {
                    let term = session.registry.get_or_build(*key, || {
                        let proj_src = sources.project(subset);
                        let proj_tgt = targets.map(|t| t.project(subset));
                        Arc::new(FktOperator::new_exec(
                            &proj_src,
                            proj_tgt.as_ref(),
                            kernel,
                            *tcfg,
                            session.coord.exec(),
                        ))
                    });
                    (w, term)
                })
                .collect();
            Arc::new(SumOp::new(terms))
        });
        OpHandle {
            op,
            kernel,
            cfg,
            dense: false,
            square: targets.is_none(),
            resolved: None,
        }
    }
}

/// A cheap, cloneable handle to a session-owned operator. Holding a handle
/// keeps the operator alive even after the registry evicts it.
#[derive(Clone)]
pub struct OpHandle {
    op: Arc<dyn KernelOp + Send + Sync>,
    kernel: Kernel,
    cfg: FktConfig,
    dense: bool,
    /// Built without `.targets(..)` — targets literally are the sources.
    square: bool,
    resolved: Option<Resolved>,
}

impl OpHandle {
    /// Number of source points.
    pub fn num_sources(&self) -> usize {
        self.op.num_sources()
    }

    /// Number of target points.
    pub fn num_targets(&self) -> usize {
        self.op.num_targets()
    }

    /// The kernel this operator applies.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// The fully resolved configuration (not meaningful for `.dense()`
    /// handles, which ignore FKT hyperparameters).
    pub fn config(&self) -> &FktConfig {
        &self.cfg
    }

    /// Resolved truncation order p.
    pub fn order(&self) -> usize {
        self.cfg.p
    }

    /// Resolved separation parameter θ.
    pub fn theta(&self) -> f64 {
        self.cfg.theta
    }

    /// Resolved storage-precision tier ([`Precision::F64`] or
    /// [`Precision::F32`] — `Auto` is resolved at build). Dense handles
    /// report `F64` (they compute in f64 throughout).
    pub fn precision(&self) -> Precision {
        self.cfg.precision
    }

    /// The tolerance resolution behind this handle, when `.tolerance(ε)`
    /// chose the hyperparameters.
    pub fn resolved(&self) -> Option<Resolved> {
        self.resolved
    }

    /// Whether this is the exact dense backend.
    pub fn is_dense(&self) -> bool {
        self.dense
    }

    /// Whether the operator is square in the strong sense — built without
    /// `.targets(..)`, so targets are the sources (a requirement for
    /// [`Session::solve`], where equal *counts* would not suffice).
    pub fn is_square(&self) -> bool {
        self.square
    }

    /// Downcast to the FKT operator (None for dense handles) — used by
    /// diagnostics (tree/plan statistics) and the solve preconditioner.
    pub fn as_fkt(&self) -> Option<&FktOperator> {
        self.op.as_fkt()
    }

    /// Downcast to the additive composite (None for plain handles) —
    /// term structure for diagnostics and tests.
    pub fn as_composite(&self) -> Option<&SumOp> {
        self.op.as_composite()
    }

    /// The shared operator itself.
    pub fn op(&self) -> &Arc<dyn KernelOp + Send + Sync> {
        &self.op
    }

    /// Whether two handles share one cached operator (registry hit).
    pub fn ptr_eq(&self, other: &OpHandle) -> bool {
        Arc::ptr_eq(&self.op, &other.op)
    }
}

/// Options for [`Session::solve`]. Borrows the noise diagonal so
/// repeated solves (every GP fit) don't copy an O(n) vector per call.
#[derive(Clone, Copy, Debug)]
pub struct SolveOpts<'a> {
    /// CG relative-residual tolerance.
    pub tol: f64,
    /// CG iteration cap.
    pub max_iters: usize,
    /// Diagonal jitter added for numerical safety.
    pub jitter: f64,
    /// Optional per-point noise variances added to the diagonal
    /// (the GP's Σ); `None` solves `(K + jitter·I) x = y`.
    pub noise: Option<&'a [f64]>,
    /// Leaf-block Jacobi preconditioning (FKT operators only; dense
    /// handles fall back to unpreconditioned CG).
    pub precondition: bool,
    /// Optional wall-clock deadline. CG stops before an iteration it does
    /// not expect to finish in time and returns the partial iterate with
    /// its honest residual (`converged: false` unless it finished anyway)
    /// — graceful degradation for deadline-aware serving.
    pub deadline: Option<Instant>,
}

impl Default for SolveOpts<'_> {
    fn default() -> Self {
        SolveOpts {
            tol: 1e-6,
            max_iters: 200,
            jitter: 1e-8,
            noise: None,
            precondition: true,
            deadline: None,
        }
    }
}

/// Leaf-block Jacobi preconditioner: per-leaf Cholesky factors of
/// `K_leaf + Σ_leaf + jitter·I`. The FKT tree's leaves capture exactly the
/// short-range couplings that make kernel systems ill-conditioned (e.g.
/// dense along-track satellite sampling), cutting CG iterations by an
/// order of magnitude (EXPERIMENTS.md §Perf).
struct BlockJacobi {
    /// Per-leaf (original indices, Cholesky factor).
    blocks: Vec<(Vec<usize>, Mat)>,
}

impl BlockJacobi {
    fn build(op: &FktOperator, noise: &[f64], jitter: f64) -> BlockJacobi {
        let kernel = &op.kernel;
        let tree = op.tree();
        let mut blocks = Vec::with_capacity(tree.leaves.len());
        for &leaf in &tree.leaves {
            let node = &tree.nodes[leaf];
            let idx: Vec<usize> = (node.start..node.end).map(|i| tree.perm[i]).collect();
            let m = idx.len();
            let mut k = Mat::zeros(m, m);
            for a in 0..m {
                // tree.points are kernel-scaled; canonical profile applies.
                let pa = tree.points.point(node.start + a);
                for b in 0..=a {
                    let pb = tree.points.point(node.start + b);
                    let r = crate::linalg::vecops::dist2(pa, pb).sqrt();
                    let v = if r == 0.0 {
                        kernel.family.value_at_zero()
                    } else {
                        kernel.family.eval(r)
                    };
                    k[(a, b)] = v;
                    k[(b, a)] = v;
                }
                k[(a, a)] += noise[idx[a]] + jitter;
            }
            let l = cholesky(&k).unwrap_or_else(|| {
                // Extremely degenerate block: fall back to the diagonal.
                let mut dl = Mat::zeros(m, m);
                for a in 0..m {
                    dl[(a, a)] = k[(a, a)].max(jitter).sqrt();
                }
                dl
            });
            blocks.push((idx, l));
        }
        BlockJacobi { blocks }
    }

    fn apply(&self, r: &[f64]) -> Vec<f64> {
        let mut z = vec![0.0; r.len()];
        let mut rl = Vec::new();
        for (idx, l) in &self.blocks {
            rl.clear();
            rl.extend(idx.iter().map(|&i| r[i]));
            let sol = cholesky_solve(l, &rl);
            for (slot, &i) in idx.iter().enumerate() {
                z[i] = sol[slot];
            }
        }
        z
    }

    /// Column-wise application to an `n·m` column-major block: the same
    /// per-leaf Cholesky factors serve every column (the factorization is
    /// the expensive part — substitutions are cheap), so a batched solve
    /// pays the build once rather than once per right-hand side.
    /// All-zero columns (the batched CG zeroes a column's residual when it
    /// freezes) skip the substitutions entirely — their preimage is zero.
    fn apply_batch(&self, r: &[f64], m: usize) -> Vec<f64> {
        let n = r.len() / m;
        let live: Vec<bool> = (0..m)
            .map(|c| r[c * n..(c + 1) * n].iter().any(|&v| v != 0.0))
            .collect();
        let mut z = vec![0.0; r.len()];
        let mut rl = Vec::new();
        for (idx, l) in &self.blocks {
            for c in 0..m {
                if !live[c] {
                    continue;
                }
                let col = &r[c * n..(c + 1) * n];
                rl.clear();
                rl.extend(idx.iter().map(|&i| col[i]));
                let sol = cholesky_solve(l, &rl);
                for (slot, &i) in idx.iter().enumerate() {
                    z[c * n + i] = sol[slot];
                }
            }
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{dense_matrix, dense_mvm};
    use crate::rng::Pcg32;

    fn uniform_points(n: usize, d: usize, seed: u64) -> Points {
        let mut rng = Pcg32::seeded(seed);
        Points::new(d, rng.uniform_vec(n * d, 0.0, 1.0))
    }

    fn rel_err(a: &[f64], b: &[f64]) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (x, y) in a.iter().zip(b) {
            num += (x - y) * (x - y);
            den += y * y;
        }
        (num / den.max(1e-300)).sqrt()
    }

    #[test]
    fn session_mvm_matches_direct_operator() {
        let pts = uniform_points(500, 2, 701);
        let mut rng = Pcg32::seeded(702);
        let w = rng.normal_vec(500);
        // One thread: the session path then reduces in exactly the serial
        // operator's order, so the comparison is to round-off.
        let session = Session::native(1);
        let h = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(4)
            .theta(0.5)
            .leaf_capacity(64)
            .build();
        let z = session.mvm(&h, &w);
        let cfg = FktConfig { p: 4, theta: 0.5, leaf_capacity: 64, ..Default::default() };
        let direct = FktOperator::square(&pts, Kernel::canonical(Family::Cauchy), cfg).matvec(&w);
        for i in 0..500 {
            assert!((z[i] - direct[i]).abs() < 1e-12 * (1.0 + direct[i].abs()), "i={i}");
        }
    }

    #[test]
    fn repeated_requests_hit_the_registry() {
        let pts = uniform_points(400, 2, 703);
        let session = Session::native(1);
        let a = session.operator(&pts).kernel(Family::Gaussian).order(4).theta(0.5).build();
        let b = session.operator(&pts).kernel(Family::Gaussian).order(4).theta(0.5).build();
        assert!(a.ptr_eq(&b), "identical requests must share one operator");
        let s = session.registry_stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        // A different configuration is a different operator.
        let c = session.operator(&pts).kernel(Family::Gaussian).order(5).theta(0.5).build();
        assert!(!a.ptr_eq(&c));
        assert_eq!(session.registry_stats().misses, 2);
        // A perturbed dataset is a different operator.
        let mut pts2 = pts.clone();
        pts2.point_mut(0)[0] += 1e-13;
        let d = session.operator(&pts2).kernel(Family::Gaussian).order(4).theta(0.5).build();
        assert!(!a.ptr_eq(&d));
    }

    #[test]
    fn registry_capacity_bounds_memory() {
        let session = Session::builder()
            .threads(1)
            .backend(Backend::Native)
            .registry_capacity(2)
            .build();
        let pts = uniform_points(200, 2, 704);
        for p in 2..6 {
            let _ = session.operator(&pts).kernel(Family::Cauchy).order(p).theta(0.5).build();
        }
        let s = session.registry_stats();
        assert!(s.len <= 2, "len {} exceeds capacity", s.len);
        // Four misses against capacity 2: whatever the shard striping,
        // every built-but-not-resident operator must have been evicted.
        assert_eq!(s.misses, 4);
        assert_eq!(s.evictions, s.misses - s.len as u64);
    }

    #[test]
    fn tolerance_resolves_and_explicit_overrides_win() {
        let pts = uniform_points(300, 2, 705);
        let session = Session::native(1);
        let auto = session.operator(&pts).kernel(Family::Matern52).tolerance(1e-5).build();
        let res = auto.resolved().expect("tolerance path resolves");
        assert!(res.bound <= 1e-5);
        assert_eq!(auto.order(), res.p);
        assert!((auto.theta() - res.theta).abs() < 1e-15);
        // Explicit order wins over the resolved one; θ stays resolved. The
        // handle then reports no resolution — its (p, θ) are not the
        // resolver's choice.
        let forced =
            session.operator(&pts).kernel(Family::Matern52).tolerance(1e-5).order(3).build();
        assert_eq!(forced.order(), 3);
        assert!((forced.theta() - res.theta).abs() < 1e-15);
        assert!(forced.resolved().is_none());
        // Fully-forced hyperparameters skip resolution entirely — even an
        // unattainable ε must not panic when it would be ignored anyway.
        let pinned = session
            .operator(&pts)
            .kernel(Family::Matern52)
            .tolerance(1e-30)
            .order(4)
            .theta(0.5)
            .build();
        assert_eq!((pinned.order(), pinned.theta()), (4, 0.5));
        // Tolerance resolutions are cached: same request re-resolves free
        // and yields the same hyperparameters.
        let again = session.operator(&pts).kernel(Family::Matern52).tolerance(1e-5).build();
        assert!(auto.ptr_eq(&again));
    }

    #[test]
    fn panel_budget_is_part_of_the_registry_key() {
        let pts = uniform_points(200, 2, 722);
        let mut rng = Pcg32::seeded(723);
        let w = rng.normal_vec(200);
        let session = Session::native(1);
        let cached = session.operator(&pts).kernel(Family::Cauchy).order(3).theta(0.5).build();
        let streamed = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(3)
            .theta(0.5)
            .panel_budget(0)
            .build();
        assert!(!cached.ptr_eq(&streamed), "budgets key distinct operators");
        let streamed2 = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(3)
            .theta(0.5)
            .panel_budget(0)
            .build();
        assert!(streamed.ptr_eq(&streamed2), "equal budgets share one operator");
        // Builder-order independence: a wholesale `.config(..)` after
        // `.panel_budget(0)` must not clobber the budget.
        let cfg = FktConfig { p: 3, theta: 0.5, ..Default::default() };
        let reordered = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .panel_budget(0)
            .config(cfg)
            .build();
        assert!(streamed.ptr_eq(&reordered), "budget survives a later .config()");
        // And both answer identically.
        let zc = session.mvm(&cached, &w);
        let zs = session.mvm(&streamed, &w);
        for (a, b) in zc.iter().zip(&zs) {
            assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()));
        }
        assert_eq!(session.last_metrics().panels_cached, 0, "budget 0 streams");
    }

    /// Registry key separation by tier: the same spec at F32 and F64 is
    /// two distinct cached operators, repeated requests hit pointer-equal
    /// per tier, and an Auto request shares its resolved tier's entry.
    #[test]
    fn precision_tiers_key_distinct_operators() {
        let pts = uniform_points(300, 2, 750);
        let mut rng = Pcg32::seeded(751);
        let w = rng.normal_vec(300);
        let session = Session::native(1);
        let spec = |s: &Session, p: Precision| {
            s.operator(&pts).kernel(Family::Gaussian).order(4).theta(0.5).precision(p).build()
        };
        let h64 = spec(&session, Precision::F64);
        let h32 = spec(&session, Precision::F32);
        assert!(!h64.ptr_eq(&h32), "tiers must cache separately");
        assert_eq!(h64.precision(), Precision::F64);
        assert_eq!(h32.precision(), Precision::F32);
        // Pointer-equal hits within each tier.
        assert!(h64.ptr_eq(&spec(&session, Precision::F64)));
        assert!(h32.ptr_eq(&spec(&session, Precision::F32)));
        let s = session.registry_stats();
        assert_eq!((s.hits, s.misses), (2, 2));
        // An Auto request with a loose tolerance resolves to F32 and
        // shares the explicit-F32 entry for the same resolved (p, θ).
        let auto = session
            .operator(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .build();
        assert_eq!(auto.precision(), Precision::F32);
        let pinned = session
            .operator(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .precision(Precision::F32)
            .build();
        assert!(auto.ptr_eq(&pinned), "Auto shares its resolved tier's cache entry");
        // And the two tiers answer within the f32 storage-rounding bound.
        let z64 = session.mvm(&h64, &w);
        let z32 = session.mvm(&h32, &w);
        assert!(rel_err(&z32, &z64) <= 5e-6);
    }

    /// The Auto rule end to end: loose ε picks f32, tight ε (or no ε at
    /// all) keeps f64 — never f32 below ε = 1e-5.
    #[test]
    fn auto_precision_follows_tolerance() {
        let pts = uniform_points(250, 2, 752);
        let session = Session::native(1);
        let at = |s: &Session, eps: f64| {
            s.operator(&pts).kernel(Family::Gaussian).tolerance(eps).build().precision()
        };
        assert_eq!(at(&session, 1e-2), Precision::F32);
        assert_eq!(at(&session, 1e-4), Precision::F32);
        assert_eq!(at(&session, 1e-5), Precision::F32);
        assert_eq!(at(&session, 9e-6), Precision::F64);
        assert_eq!(at(&session, 1e-6), Precision::F64);
        // No tolerance ⇒ conservative f64.
        let h = session.operator(&pts).kernel(Family::Gaussian).order(4).theta(0.5).build();
        assert_eq!(h.precision(), Precision::F64);
        // Explicit precision beats the rule in both directions, and a
        // `.config(..)`-carried tier survives builder-call order.
        let forced = session
            .operator(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-2)
            .precision(Precision::F64)
            .build();
        assert_eq!(forced.precision(), Precision::F64);
        let cfg = FktConfig { p: 4, theta: 0.5, precision: Precision::F32, ..Default::default() };
        let via_cfg = session.operator(&pts).kernel(Family::Gaussian).config(cfg).build();
        assert_eq!(via_cfg.precision(), Precision::F32);
        // An EXPLICIT `.precision(Auto)` re-engages the tolerance rule
        // even over a `.config(..)`-pinned tier: ε below the f32 floor
        // must come back f64.
        let auto_over_cfg = session
            .operator(&pts)
            .kernel(Family::Gaussian)
            .config(cfg)
            .precision(Precision::Auto)
            .tolerance(1e-6)
            .build();
        assert_eq!(auto_over_cfg.precision(), Precision::F64);
        // Dense handles canonicalize to f64 regardless.
        let dense = session
            .operator(&pts)
            .kernel(Family::Gaussian)
            .precision(Precision::F32)
            .dense()
            .build();
        assert_eq!(dense.precision(), Precision::F64);
    }

    /// `MvmMetrics` reports the tier and tier-priced panel residency:
    /// the f32 operator's resident bytes are exactly half the f64 one's.
    #[test]
    fn metrics_report_tier_and_halved_panel_bytes() {
        let pts = uniform_points(400, 2, 753);
        let mut rng = Pcg32::seeded(754);
        let w = rng.normal_vec(400);
        let session = Session::native(2);
        let h64 = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(4)
            .theta(0.5)
            .leaf_capacity(64)
            .build();
        let _ = session.mvm(&h64, &w);
        let m64 = session.last_metrics();
        assert_eq!(m64.precision, Precision::F64);
        assert!(m64.panel_bytes > 0);
        let h32 = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(4)
            .theta(0.5)
            .leaf_capacity(64)
            .precision(Precision::F32)
            .build();
        let _ = session.mvm(&h32, &w);
        let m32 = session.last_metrics();
        assert_eq!(m32.precision, Precision::F32);
        assert_eq!(m32.panel_bytes * 2, m64.panel_bytes, "halved residency under f32");
        assert_eq!(m32.panels_cached, m64.panels_cached);
    }

    /// The refined-solve acceptance: a solve against the f32-tier operator
    /// must reach the SAME residual tolerance as the pure-f64 solve on a
    /// GP-style workload, with the sweeps surfaced in `SessionCounters`.
    #[test]
    fn refined_f32_solve_matches_f64_solve() {
        let n = 250;
        let pts = uniform_points(n, 2, 755);
        let mut rng = Pcg32::seeded(756);
        let y = rng.normal_vec(n);
        let noise: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 0.2)).collect();
        let kernel = Kernel::matern32(0.5);
        let session = Session::native(2);
        let build = |s: &Session, p: Precision| {
            s.operator(&pts)
                .scaled_kernel(kernel)
                .order(6)
                .theta(0.4)
                .leaf_capacity(32)
                .precision(p)
                .build()
        };
        let h64 = build(&session, Precision::F64);
        let h32 = build(&session, Precision::F32);
        for precondition in [true, false] {
            let opts = SolveOpts {
                tol: 1e-8,
                max_iters: 800,
                jitter: 1e-8,
                noise: Some(&noise),
                precondition,
                deadline: None,
            };
            let sweeps_before = session.counters().refine_sweeps;
            let pure = session.solve(&h64, &y, &opts);
            assert!(pure.converged, "precondition={precondition}");
            assert_eq!(
                session.counters().refine_sweeps,
                sweeps_before,
                "f64-tier solves never sweep"
            );
            let refined = session.solve(&h32, &y, &opts);
            let sweeps = session.counters().refine_sweeps - sweeps_before;
            assert!(
                refined.converged,
                "precondition={precondition}: refined residual {}",
                refined.rel_residual
            );
            assert!(refined.rel_residual <= opts.tol, "same tolerance as the f64 solve");
            assert!(sweeps >= 1, "refinement must sweep at least once");
            assert!(sweeps <= 8, "well-conditioned system converges in few sweeps: {sweeps}");
            // Both solved the same (f64) system to 1e-8: solutions agree
            // to κ·tol, far beyond what a raw f32 solve could promise.
            let e = rel_err(&refined.x, &pure.x);
            assert!(e <= 1e-4, "precondition={precondition}: refined vs pure rel err {e}");
        }
    }

    /// Batched refined solve: column c matches its own single refined
    /// solve (the lockstep inner CG preserves the per-column recurrence,
    /// and the outer corrections are column-independent).
    #[test]
    fn refined_solve_batch_columns_match_single() {
        let n = 200;
        let m = 3;
        let pts = uniform_points(n, 2, 757);
        let mut rng = Pcg32::seeded(758);
        let ys = rng.normal_vec(n * m);
        let noise: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.3, 0.5)).collect();
        let kernel = Kernel::matern32(0.4);
        let session = Session::native(1);
        let h32 = session
            .operator(&pts)
            .scaled_kernel(kernel)
            .order(6)
            .theta(0.4)
            .leaf_capacity(32)
            .precision(Precision::F32)
            .build();
        let opts = SolveOpts {
            tol: 1e-8,
            max_iters: 600,
            jitter: 1e-8,
            noise: Some(&noise),
            precondition: true,
            deadline: None,
        };
        let sweeps_before = session.counters().refine_sweeps;
        let batch = session.solve_batch(&h32, &ys, m, &opts);
        let batch_sweeps = session.counters().refine_sweeps - sweeps_before;
        assert!(batch.all_converged());
        assert!(batch_sweeps >= 1);
        for c in 0..m {
            let single = session.solve(&h32, &ys[c * n..(c + 1) * n], &opts);
            assert!(single.converged);
            assert!(single.rel_residual <= opts.tol);
            for i in 0..n {
                let (b, s) = (batch.x[c * n + i], single.x[i]);
                assert!(
                    (b - s).abs() <= 1e-8 * (1.0 + s.abs()),
                    "col={c} i={i}: {b} vs {s}"
                );
            }
        }
    }

    #[test]
    fn transient_requests_bypass_the_registry() {
        let pts = uniform_points(300, 2, 718);
        let mut rng = Pcg32::seeded(719);
        let w = rng.normal_vec(300);
        let session = Session::native(1);
        let a = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(4)
            .theta(0.5)
            .transient()
            .build();
        let b = session
            .operator(&pts)
            .kernel(Family::Cauchy)
            .order(4)
            .theta(0.5)
            .transient()
            .build();
        assert!(!a.ptr_eq(&b), "transient builds are never shared");
        let s = session.registry_stats();
        assert_eq!((s.hits, s.misses, s.len), (0, 0, 0), "registry untouched");
        // The handle still works through every session verb.
        let za = session.mvm(&a, &w);
        let zb = session.mvm(&b, &w);
        for (x, y) in za.iter().zip(&zb) {
            assert!((x - y).abs() <= 1e-12 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn dense_handles_are_cached_separately() {
        let pts = uniform_points(250, 2, 706);
        let mut rng = Pcg32::seeded(707);
        let w = rng.normal_vec(250);
        let session = Session::native(1);
        let fast = session.operator(&pts).kernel(Family::Cauchy).order(6).theta(0.4).build();
        let exact = session.operator(&pts).kernel(Family::Cauchy).dense().build();
        assert!(exact.is_dense());
        assert!(exact.as_fkt().is_none());
        assert!(!fast.ptr_eq(&exact));
        let zf = session.mvm(&fast, &w);
        let ze = session.mvm(&exact, &w);
        assert!(rel_err(&zf, &ze) < 1e-4, "backends disagree");
        // Dense requests cache like any other, and FKT hyperparameters —
        // which the dense backend ignores — don't fragment the key.
        let exact2 = session.operator(&pts).kernel(Family::Cauchy).dense().build();
        assert!(exact.ptr_eq(&exact2));
        let exact3 =
            session.operator(&pts).kernel(Family::Cauchy).order(9).theta(0.2).dense().build();
        assert!(exact.ptr_eq(&exact3));
    }

    #[test]
    fn mvm_batch_matches_looped_mvm() {
        let pts = uniform_points(400, 2, 708);
        let mut rng = Pcg32::seeded(709);
        let w = rng.normal_vec(400 * 3);
        let session = Session::native(4);
        let h = session.operator(&pts).kernel(Family::Cauchy).order(4).theta(0.5).build();
        let batched = session.mvm_batch(&h, &w, 3);
        assert_eq!(session.last_metrics().moment_passes, 1);
        for c in 0..3 {
            let single = session.mvm(&h, &w[c * 400..(c + 1) * 400]);
            for t in 0..400 {
                let b = batched[c * 400 + t];
                assert!((b - single[t]).abs() <= 1e-12 * (1.0 + single[t].abs()), "c={c} t={t}");
            }
        }
    }

    #[test]
    fn solve_matches_dense_cholesky() {
        let n = 220;
        let pts = uniform_points(n, 2, 710);
        let mut rng = Pcg32::seeded(711);
        let y = rng.normal_vec(n);
        let noise: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.05, 0.1)).collect();
        let kernel = Kernel::matern32(0.5);
        // Dense oracle.
        let mut k = dense_matrix(&kernel, &pts, &pts);
        for i in 0..n {
            k[(i, i)] += noise[i] + 1e-8;
        }
        let l = cholesky(&k).expect("SPD");
        let oracle = cholesky_solve(&l, &y);
        let session = Session::native(2);
        let h = session
            .operator(&pts)
            .scaled_kernel(kernel)
            .order(8)
            .theta(0.3)
            .leaf_capacity(32)
            .build();
        for precondition in [true, false] {
            let opts = SolveOpts {
                tol: 1e-8,
                max_iters: 800,
                jitter: 1e-8,
                noise: Some(&noise),
                precondition,
                deadline: None,
            };
            let sol = session.solve(&h, &y, &opts);
            assert!(sol.converged, "precondition={precondition}: residual {}", sol.rel_residual);
            let e = rel_err(&sol.x, &oracle);
            assert!(e < 1e-3, "precondition={precondition}: rel err {e}");
        }
    }

    #[test]
    fn solve_honors_an_expired_deadline_with_a_partial_result() {
        let n = 200;
        let pts = uniform_points(n, 2, 715);
        let mut rng = Pcg32::seeded(716);
        let y = rng.normal_vec(n);
        let noise: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.05, 0.1)).collect();
        let session = Session::native(1);
        let h = session
            .operator(&pts)
            .scaled_kernel(Kernel::matern32(0.5))
            .order(6)
            .theta(0.3)
            .leaf_capacity(32)
            .build();
        let expired = SolveOpts {
            noise: Some(&noise),
            deadline: Some(std::time::Instant::now() - std::time::Duration::from_millis(1)),
            ..SolveOpts::default()
        };
        let partial = session.solve(&h, &y, &expired);
        assert_eq!(partial.iterations, 0, "expired deadline must stop before iterating");
        assert!(!partial.converged);
        assert!((partial.rel_residual - 1.0).abs() < 1e-12, "zero iterate residual is ‖y‖/‖y‖");
        // A generous deadline behaves exactly like no deadline.
        let generous = SolveOpts {
            noise: Some(&noise),
            deadline: Some(std::time::Instant::now() + std::time::Duration::from_secs(600)),
            ..SolveOpts::default()
        };
        let full = session.solve(&h, &y, &generous);
        let plain_opts = SolveOpts { noise: Some(&noise), ..SolveOpts::default() };
        let plain = session.solve(&h, &y, &plain_opts);
        assert!(full.converged);
        assert_eq!(full.iterations, plain.iterations);
        assert_eq!(full.x, plain.x);
        // Batched path: expired deadline freezes every column at zero.
        let m = 3;
        let ys = rng.normal_vec(n * m);
        let batch = session.solve_batch(&h, &ys, m, &expired);
        for c in 0..m {
            assert_eq!(batch.iterations[c], 0, "col {c}");
            assert!(!batch.converged[c], "col {c}");
        }
    }

    #[test]
    fn solve_batch_columns_match_looped_solve() {
        // The tentpole equivalence: each column of one batched solve must
        // match its own single-RHS session solve to ≤ 1e-10, with and
        // without the (shared) block-Jacobi preconditioner.
        // Single-threaded, solidly conditioned (noise ≥ 0.3) so both runs
        // sit deep inside CG's convergent regime and the only perturbation
        // between them is the fused-vs-single MVM round-off (≤ 1e-12).
        let n = 250;
        let m = 5;
        let pts = uniform_points(n, 2, 730);
        let mut rng = Pcg32::seeded(731);
        let ys = rng.normal_vec(n * m);
        let noise: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.3, 0.5)).collect();
        let kernel = Kernel::matern32(0.4);
        let session = Session::native(1);
        let h = session
            .operator(&pts)
            .scaled_kernel(kernel)
            .order(6)
            .theta(0.4)
            .leaf_capacity(32)
            .build();
        for precondition in [true, false] {
            let opts = SolveOpts {
                tol: 1e-11,
                max_iters: 400,
                jitter: 1e-8,
                noise: Some(&noise),
                precondition,
                deadline: None,
            };
            let batch = session.solve_batch(&h, &ys, m, &opts);
            assert!(batch.all_converged(), "precondition={precondition}");
            for c in 0..m {
                let single = session.solve(&h, &ys[c * n..(c + 1) * n], &opts);
                assert!(single.converged);
                for i in 0..n {
                    let (b, s) = (batch.x[c * n + i], single.x[i]);
                    assert!(
                        (b - s).abs() <= 1e-10 * (1.0 + s.abs()),
                        "precondition={precondition} col={c} i={i}: {b} vs {s}"
                    );
                }
            }
            // The whole batch cost one fused traversal per CG iteration,
            // not one per (column × iteration).
            let max_iters_taken = *batch.iterations.iter().max().unwrap();
            assert_eq!(batch.batched_mvms, max_iters_taken, "precondition={precondition}");
        }
    }

    #[test]
    fn session_counters_record_each_verb() {
        let pts = uniform_points(150, 2, 732);
        let mut rng = Pcg32::seeded(733);
        let w = rng.normal_vec(150 * 2);
        let session = Session::native(1);
        assert_eq!(session.counters(), SessionCounters::default());
        let h = session.operator(&pts).kernel(Family::Gaussian).order(3).theta(0.5).build();
        let _ = session.mvm(&h, &w[..150]);
        let _ = session.mvm_batch(&h, &w, 2);
        let _ = session.solve(&h, &w[..150], &SolveOpts::default());
        let _ = session.solve_batch(&h, &w, 2, &SolveOpts::default());
        let c = session.counters();
        assert_eq!((c.mvm, c.mvm_batch, c.solve, c.solve_batch), (1, 1, 1, 1));
    }

    #[test]
    #[should_panic(expected = "square operator")]
    fn solve_rejects_rectangular_operator_even_with_equal_counts() {
        // 100 sources and 100 *different* targets: counts match but the
        // system is not symmetric — solve must refuse.
        let src = uniform_points(100, 2, 720);
        let tgt = uniform_points(100, 2, 721);
        let session = Session::native(1);
        let h = session
            .operator(&src)
            .targets(&tgt)
            .kernel(Family::Gaussian)
            .order(3)
            .theta(0.5)
            .build();
        let y = vec![1.0; 100];
        let _ = session.solve(&h, &y, &SolveOpts::default());
    }

    #[test]
    fn tolerance_yields_measured_error_within_eps() {
        // The tentpole promise in one unit test (the integration suite
        // sweeps more kernels): auto-tuned (p, θ) must deliver ≤ ε
        // measured against the exact dense sum.
        let pts = uniform_points(600, 2, 712);
        let mut rng = Pcg32::seeded(713);
        let w = rng.normal_vec(600);
        let kern = Kernel::canonical(Family::Gaussian);
        let dense = dense_mvm(&kern, &pts, &pts, &w);
        let session = Session::native(2);
        for eps in [1e-3, 1e-6] {
            let h = session
                .operator(&pts)
                .kernel(Family::Gaussian)
                .tolerance(eps)
                .leaf_capacity(64)
                .build();
            let z = session.mvm(&h, &w);
            let e = rel_err(&z, &dense);
            assert!(e <= eps, "eps={eps}: measured {e} (resolved {:?})", h.resolved());
        }
    }

    #[test]
    fn rectangular_request_through_session() {
        let src = uniform_points(300, 2, 714);
        let tgt = uniform_points(120, 2, 715);
        let mut rng = Pcg32::seeded(716);
        let w = rng.normal_vec(300);
        let kern = Kernel::canonical(Family::Gaussian);
        let dense = dense_mvm(&kern, &src, &tgt, &w);
        let session = Session::native(1);
        let h = session
            .operator(&src)
            .targets(&tgt)
            .kernel(Family::Gaussian)
            .order(5)
            .theta(0.5)
            .leaf_capacity(25)
            .build();
        assert_eq!(h.num_targets(), 120);
        let z = session.mvm(&h, &w);
        assert!(rel_err(&z, &dense) < 1e-3);
        // Swapping targets changes the key.
        let h2 = session.operator(&src).kernel(Family::Gaussian).order(5).theta(0.5).build();
        assert!(!h.ptr_eq(&h2));
    }

    #[test]
    #[should_panic(expected = "unattainable")]
    fn unattainable_tolerance_panics_with_guidance() {
        let pts = uniform_points(50, 6, 717);
        let session = Session::native(1);
        let _ = session.operator(&pts).kernel(Family::Gaussian).tolerance(1e-14).build();
    }

    /// The serving-layer contract: threads holding clones of one
    /// `Arc<SessionCore>` build the same spec concurrently, coalesce onto
    /// ONE operator build, and get pointer-equal handles.
    #[test]
    fn cross_thread_requests_share_one_cached_operator() {
        const THREADS: usize = 8;
        let pts = uniform_points(300, 2, 760);
        let session = Session::native(1);
        let core = session.clone_core();
        let ptrs: Vec<usize> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    let core = Arc::clone(&core);
                    let pts = &pts;
                    scope.spawn(move || {
                        let h = core
                            .operator(pts)
                            .kernel(Family::Cauchy)
                            .order(4)
                            .theta(0.5)
                            .build();
                        Arc::as_ptr(h.op()) as *const () as usize
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "one shared operator");
        let s = session.registry_stats();
        assert_eq!(s.misses, 1, "racing requests must coalesce onto one build");
        assert_eq!(s.hits + s.coalesced, THREADS as u64 - 1);
    }

    /// Concurrent verbs through a shared core: every thread's MVM matches
    /// the sequential answer, and the atomic counters account for every
    /// call with no lost updates.
    #[test]
    fn shared_core_serves_concurrent_mvms() {
        const THREADS: usize = 6;
        const CALLS: usize = 5;
        let pts = uniform_points(400, 2, 761);
        let mut rng = Pcg32::seeded(762);
        let w = rng.normal_vec(400);
        let session = Session::native(1);
        let h = session.operator(&pts).kernel(Family::Cauchy).order(4).theta(0.5).build();
        let expect = session.mvm(&h, &w);
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                let core = session.clone_core();
                let (h, w, expect) = (h.clone(), &w, &expect);
                scope.spawn(move || {
                    for _ in 0..CALLS {
                        let z = core.mvm(&h, w);
                        for (a, b) in z.iter().zip(expect) {
                            assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()));
                        }
                    }
                });
            }
        });
        let c = session.counters();
        assert_eq!(c.mvm, (THREADS * CALLS) as u64 + 1, "no lost counter updates");
    }

    #[test]
    fn subsets_parse_and_materialize() {
        assert_eq!(Subsets::parse("random:8x3"), Ok(Subsets::Random { k: 8, arity: 3 }));
        assert_eq!(
            Subsets::parse("0,2;1,3"),
            Ok(Subsets::Explicit(vec![vec![0, 2], vec![1, 3]]))
        );
        assert!(Subsets::parse("random:8").is_err());
        assert!(Subsets::parse("").is_err());
        assert!(Subsets::parse("0,x").is_err());

        let subs = Subsets::Random { k: 6, arity: 3 }.materialize(10, 42).unwrap();
        assert_eq!(subs.len(), 6);
        for s in &subs {
            assert_eq!(s.len(), 3);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted distinct axes");
            assert!(s.iter().all(|&a| a < 10));
        }
        // Deterministic in the seed; distinct subsets while possible.
        assert_eq!(subs, Subsets::Random { k: 6, arity: 3 }.materialize(10, 42).unwrap());
        assert!(subs.windows(2).all(|w| w[0] != w[1]));
        // Explicit subsets canonicalize (sort + dedup) and validate.
        assert_eq!(
            Subsets::Explicit(vec![vec![2, 0, 2]]).materialize(3, 0).unwrap(),
            vec![vec![0, 2]]
        );
        assert!(Subsets::Explicit(vec![vec![3]]).materialize(3, 0).is_err());
        assert!(Subsets::Random { k: 2, arity: 4 }.materialize(3, 0).is_err());
    }

    /// The headline acceptance invariant: a composite additive operator
    /// matches the dense additive baseline to the requested tolerance on
    /// d = 10 and d = 20 synthetic data, for both ε = 1e-2 and 1e-4, and
    /// its batched apply costs exactly one traversal per term.
    #[test]
    fn additive_composite_meets_tolerance_in_high_dimension() {
        let session = Session::native(2);
        for (d, n, seed) in [(10usize, 600usize, 771u64), (20, 600, 772)] {
            let pts = uniform_points(n, d, seed);
            let mut rng = Pcg32::seeded(seed + 1);
            let w = rng.normal_vec(n);
            for eps in [1e-2, 1e-4] {
                let spec = session
                    .additive(&pts)
                    .kernel(Family::Gaussian)
                    .tolerance(eps)
                    .subsets(Subsets::Random { k: 8, arity: 3 })
                    .seed(9 + d as u64);
                let subs = spec.materialized_subsets();
                let h = spec.build();
                assert_eq!(h.as_composite().unwrap().num_terms(), 8);
                let z = session.mvm(&h, &w);
                let kern = Kernel::canonical(Family::Gaussian);
                let exact =
                    crate::baselines::dense_additive_mvm(&kern, &pts, None, &subs, &[1.0; 8], &w);
                let err = rel_err(&z, &exact);
                assert!(err <= eps, "d={d} eps={eps}: rel err {err:.3e}");
            }
        }
    }

    #[test]
    fn additive_batch_is_one_traversal_per_term() {
        let pts = uniform_points(500, 12, 773);
        let mut rng = Pcg32::seeded(774);
        let w = rng.normal_vec(500 * 4);
        let session = Session::native(2);
        let h = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .subsets(Subsets::Random { k: 5, arity: 2 })
            .build();
        let _ = session.mvm_batch(&h, &w, 4);
        let m = session.last_metrics();
        assert_eq!(m.columns, 4);
        // 5 terms × 1 fused traversal each — NOT 5 × 4 columns.
        assert_eq!((m.moment_passes, m.far_passes, m.near_passes), (5, 5, 5));
    }

    #[test]
    fn overlapping_subsets_share_term_arcs_across_composites() {
        let pts = uniform_points(300, 8, 775);
        let session = Session::native(1);
        let shared = vec![1usize, 4];
        let a = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .subsets(Subsets::Explicit(vec![shared.clone(), vec![0, 2]]))
            .build();
        let b = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .subsets(Subsets::Explicit(vec![shared.clone(), vec![3, 5]]))
            .build();
        assert!(!a.ptr_eq(&b), "different multisets are different composites");
        // The overlapping subset's term is one Arc, shared through the
        // registry across both composites.
        let term_of = |h: &OpHandle, slot: usize| {
            Arc::as_ptr(&h.as_composite().unwrap().terms()[slot].1) as *const ()
        };
        assert_eq!(term_of(&a, 0), term_of(&b, 0), "shared subset shares its operator");
        assert_ne!(term_of(&a, 1), term_of(&b, 1));
        // Same subsets in a different order: the multiset key makes it the
        // SAME composite (pointer-equal), weights being uniform.
        let c = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .subsets(Subsets::Explicit(vec![vec![2, 0], shared.clone()]))
            .build();
        assert!(a.ptr_eq(&c), "multiset keying is order-independent");
        // Different weights are a different composite.
        let d = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .subsets(Subsets::Explicit(vec![shared, vec![0, 2]]))
            .weights(vec![2.0, 1.0])
            .build();
        assert!(!a.ptr_eq(&d));
    }

    #[test]
    fn composite_survives_registry_eviction_and_clear() {
        let session = Session::builder()
            .threads(1)
            .backend(Backend::Native)
            .registry_capacity(2)
            .build();
        let pts = uniform_points(250, 6, 776);
        let mut rng = Pcg32::seeded(777);
        let w = rng.normal_vec(250);
        let subs = vec![vec![0usize, 1], vec![2, 3], vec![4, 5]];
        let h = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-3)
            .subsets(Subsets::Explicit(subs.clone()))
            .build();
        let before = session.mvm(&h, &w);
        // Churn the tiny registry until every sub-term (and the composite
        // entry itself) has been evicted, then drop the rest for good
        // measure: the handle holds its own Arcs, so it must keep working.
        for p in 2..8 {
            let _ = session.operator(&pts).kernel(Family::Cauchy).order(p).theta(0.5).build();
        }
        session.clear_registry();
        let after = session.mvm(&h, &w);
        assert_eq!(before, after, "live composite must not notice eviction");
        let kern = Kernel::canonical(Family::Gaussian);
        let exact =
            crate::baselines::dense_additive_mvm(&kern, &pts, None, &subs, &[1.0, 1.0, 1.0], &w);
        assert!(rel_err(&after, &exact) <= 1e-3);
    }

    #[test]
    fn concurrent_composite_builds_share_one_build() {
        let pts = uniform_points(400, 10, 778);
        let session = Session::native(2);
        let build = |core: &Arc<SessionCore>| {
            core.additive(&pts)
                .kernel(Family::Gaussian)
                .tolerance(1e-3)
                .subsets(Subsets::Random { k: 4, arity: 3 })
                .seed(11)
                .build()
        };
        let (a, b) = std::thread::scope(|scope| {
            let c1 = session.clone_core();
            let c2 = session.clone_core();
            let h1 = scope.spawn(move || build(&c1));
            let h2 = scope.spawn(move || build(&c2));
            (h1.join().unwrap(), h2.join().unwrap())
        });
        assert!(a.ptr_eq(&b), "racing tenants share one composite");
        // Exactly one build per term plus one for the composite, however
        // the race resolved (the loser hit or coalesced at some level).
        assert_eq!(session.registry_stats().misses, 4 + 1);
    }

    #[test]
    fn composite_solve_matches_dense_oracle() {
        let n = 140;
        let pts = uniform_points(n, 6, 779);
        let mut rng = Pcg32::seeded(780);
        let y = rng.normal_vec(n);
        let subs = vec![vec![0usize, 1, 2], vec![3, 4], vec![1, 5]];
        let session = Session::native(1);
        let h = session
            .additive(&pts)
            .kernel(Family::Gaussian)
            .tolerance(1e-5)
            .precision(Precision::F64)
            .subsets(Subsets::Explicit(subs.clone()))
            .build();
        assert!(h.is_square());
        let noise = vec![0.1; n];
        let opts = SolveOpts { noise: Some(&noise), tol: 1e-8, ..Default::default() };
        let sol = session.solve(&h, &y, &opts);
        assert!(sol.converged, "composite CG converged (rel {})", sol.rel_residual);
        // Dense oracle: (Σ_t K_t + Σ + jitter·I) x = y by Cholesky.
        let kern = Kernel::canonical(Family::Gaussian);
        let mut kmat = Mat::zeros(n, n);
        for s in &subs {
            let proj = pts.project(s);
            let term = dense_matrix(&kern, &proj, &proj);
            for i in 0..n {
                for j in 0..n {
                    kmat[(i, j)] += term[(i, j)];
                }
            }
        }
        for i in 0..n {
            kmat[(i, i)] += noise[i] + opts.jitter;
        }
        let l = cholesky(&kmat).expect("SPD");
        let exact = cholesky_solve(&l, &y);
        let err = rel_err(&sol.x, &exact);
        assert!(err < 1e-4, "solve rel err {err:.3e}");
    }
}
