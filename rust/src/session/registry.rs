//! The session's keyed operator registry — a sharded, lock-striped
//! concurrent store.
//!
//! An FKT operator is expensive to build (tree + interaction plan + exact
//! expansion coefficients) but cheap to *reuse* — the whole point of a
//! service handling many requests over the same dataset. The registry maps
//! a structural key — dataset fingerprint(s) × kernel × fully resolved
//! configuration — to a cached `Arc<dyn KernelOp>`, so a repeated request
//! returns the *same* operator (pointer-equal Arc) without rebuilding.
//!
//! **Fingerprinting.** Datasets have no identity of their own (`Points` is
//! a plain coordinate buffer), so the registry derives one: two
//! independent word-wise hash lanes (128 bits total) over `(d, n, every
//! coordinate's f64 bit pattern)`. Any change to any coordinate changes
//! the fingerprint, so a moving dataset (t-SNE's per-iteration embedding)
//! naturally misses the cache while a static dataset (a GP's training
//! set) always hits it. The fingerprint is *probabilistic* identity: an
//! accidental collision (≈2⁻¹²⁸ for unrelated data) would serve the wrong
//! operator, and the hash is non-cryptographic — adversarially crafted
//! point sets are out of scope for this cache.
//!
//! **Concurrency.** The store is striped into shards selected by `OpKey`
//! hash; each shard is an `RwLock` around its own LRU map. A hit takes
//! only the shard's *read* lock (the LRU stamp is an atomic, so readers
//! never upgrade), which lets any number of serving threads clone a hot
//! operator concurrently. A miss takes the shard's *write* lock just long
//! enough to register an in-flight build latch, then builds **outside**
//! the lock — other shards, and even hits on the same shard, proceed
//! while an O(N log N) build runs. Threads that miss on a key whose build
//! is already in flight wait on that latch and receive the winner's Arc
//! (counted as `coalesced`), so a thundering herd on a cold operator
//! performs exactly one build. A build that panics poisons its latch;
//! waiters observe the poison and retry, so one bad spec cannot wedge the
//! shard.
//!
//! **Eviction.** Bounded LRU per shard: every hit/insert stamps a
//! monotone tick, and inserting past the shard's capacity evicts its
//! least-recently-used entry. The per-shard capacity is
//! `floor(capacity / shards)` (min 1), so the total cached population
//! never exceeds the requested capacity. Workloads that churn operators
//! (t-SNE rebuilds two per gradient step) therefore hold memory constant
//! instead of accumulating dead trees.

use crate::fkt::ExpansionCenter;
use crate::kernels::Family;
use crate::linalg::Precision;
use crate::op::KernelOp;
use crate::points::Points;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// A cached operator as handed out by the registry: shareable across
/// threads, applied through `&self`.
pub type SharedOp = Arc<dyn KernelOp + Send + Sync>;

/// Two-lane word-wise hash over an arbitrary u64 word stream. Lane 1 is
/// FNV-1a (xor-then-multiply); lane 2 multiplies first and folds in a
/// rotated word, so the lanes don't share collision structure. Two
/// multiplies per word keep the hash far cheaper than the work it guards.
/// This is the one hashing scheme behind every cache identity in the crate
/// — the registry's dataset [`fingerprint`] and the GP's representer-
/// weight `y`-fingerprint both feed it — so its mixing evolves in exactly
/// one place. See the module docs for what this probabilistic identity
/// does and does not guarantee.
pub fn fingerprint_words(words: impl IntoIterator<Item = u64>) -> u128 {
    const OFFSET1: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME1: u64 = 0x0000_0100_0000_01b3;
    const OFFSET2: u64 = 0x6c62_272e_07bb_0142;
    const PRIME2: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut h1 = OFFSET1;
    let mut h2 = OFFSET2;
    for word in words {
        h1 = (h1 ^ word).wrapping_mul(PRIME1);
        h2 = h2.wrapping_mul(PRIME2) ^ word.rotate_left(32);
    }
    ((h1 as u128) << 64) | h2 as u128
}

/// Dataset fingerprint: dimension, count, and the bit pattern of every
/// coordinate through [`fingerprint_words`].
pub fn fingerprint(points: &Points) -> u128 {
    fingerprint_words(
        [points.d as u64, points.len() as u64]
            .into_iter()
            .chain(points.coords.iter().map(|c| c.to_bits())),
    )
}

/// Fingerprint of a coordinate projection `parent[:, axes]`, derived from
/// the parent fingerprint and the axis list alone — O(arity), never
/// O(n·d). Two composites over the same dataset that pick the same subset
/// therefore key their sub-operator identically and share one Arc, while
/// any coordinate change in the parent flows through to every projection.
/// The leading tag word domain-separates projections from whole datasets.
pub fn projection_fingerprint(parent: u128, axes: &[usize]) -> u128 {
    const TAG: u64 = 0x70726f_6a656374; // "project"
    fingerprint_words(
        [TAG, parent as u64, (parent >> 64) as u64, axes.len() as u64]
            .into_iter()
            .chain(axes.iter().map(|&a| a as u64)),
    )
}

/// Fingerprint of a composite operator: the *multiset* of its weighted
/// term keys. Each `(weight, term key)` pair hashes to one word; sorting
/// the words before the final mix makes term order irrelevant, so two
/// composites listing the same subsets in different orders share a cache
/// entry. The tag word domain-separates composites from datasets and
/// projections.
pub fn composite_fingerprint(terms: &[(f64, OpKey)]) -> u128 {
    const TAG: u64 = 0x636f6d_706f7369; // "composi"
    let mut words: Vec<u64> = terms
        .iter()
        .map(|(w, k)| {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            w.to_bits().hash(&mut h);
            k.hash(&mut h);
            h.finish()
        })
        .collect();
    words.sort_unstable();
    fingerprint_words([TAG, terms.len() as u64].into_iter().chain(words))
}

/// Structural identity of one operator request. Configuration fields are
/// exact (floating-point parameters are keyed by bit pattern, not by
/// value); dataset identity is the 128-bit [`fingerprint`], so equal keys
/// build identical operators up to that fingerprint's collision bound.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OpKey {
    /// Source-dataset fingerprint.
    pub src_fp: u128,
    /// Target-dataset fingerprint; `None` for the square case.
    pub tgt_fp: Option<u128>,
    /// Kernel family.
    pub family: Family,
    /// Kernel coordinate scale (bit pattern).
    pub scale_bits: u64,
    /// Resolved truncation order p.
    pub p: usize,
    /// Resolved separation parameter θ (bit pattern).
    pub theta_bits: u64,
    /// Leaf capacity.
    pub leaf_capacity: usize,
    /// Expansion-center convention.
    pub center: ExpansionCenter,
    /// §A.4 compression toggle.
    pub compression: bool,
    /// Far-field panel-cache byte budget (`FktConfig::panel_budget_bytes`)
    /// — part of the identity because it changes the built operator's
    /// memory footprint and apply-time behavior.
    pub panel_budget: usize,
    /// Resolved storage-precision tier (`Auto` never appears here — the
    /// session resolves it before keying): the same spec at f32 and f64 is
    /// two distinct operators with different panel storage, residency, and
    /// error floor, while an `Auto` request that resolves to a tier shares
    /// that tier's cache entry.
    pub precision: Precision,
    /// Exact dense backend instead of the FKT.
    pub dense: bool,
    /// Composite (additive) operator: `src_fp` is then the multiset
    /// fingerprint of the term keys ([`composite_fingerprint`]) rather
    /// than a dataset fingerprint, and `p`/`theta_bits` are zeroed (each
    /// term resolves its own). The flag domain-separates the two keying
    /// schemes inside one map.
    pub composite: bool,
}

/// Registry counters — the observable behaviour of the cache. `hits` vs
/// `misses` is asserted in tests; `build_seconds` accumulates the time the
/// cache has *saved callers from paying again*; `coalesced` counts
/// requests that piggybacked on another thread's in-flight build instead
/// of duplicating it.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegistryStats {
    /// Requests answered from the cache.
    pub hits: u64,
    /// Requests that had to build a new operator.
    pub misses: u64,
    /// Requests that waited on another thread's in-flight build of the
    /// same key and received the winner's Arc (no duplicate build).
    pub coalesced: u64,
    /// Entries evicted by the LRU policy.
    pub evictions: u64,
    /// Total seconds spent building operators (misses only).
    pub build_seconds: f64,
    /// Current number of cached operators.
    pub len: usize,
}

struct Entry {
    op: SharedOp,
    /// LRU stamp. Atomic so cache *hits* can refresh recency under the
    /// shard's read lock — readers never need the write lock.
    last_used: AtomicU64,
}

/// One-shot rendezvous for an in-flight build. The building thread
/// fulfills (or poisons, via the panic guard) the latch exactly once;
/// any number of coalesced waiters block on the condvar.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

enum LatchState {
    Pending,
    Ready(SharedOp),
    /// The builder panicked. Waiters must retry the whole lookup (one of
    /// them will become the new builder).
    Poisoned,
}

impl Latch {
    fn new() -> Latch {
        Latch { state: Mutex::new(LatchState::Pending), cv: Condvar::new() }
    }

    fn fulfill(&self, op: SharedOp) {
        *lock_mutex(&self.state) = LatchState::Ready(op);
        self.cv.notify_all();
    }

    fn poison(&self) {
        *lock_mutex(&self.state) = LatchState::Poisoned;
        self.cv.notify_all();
    }

    /// Block until the build resolves. `None` means the builder panicked
    /// and the caller should retry the lookup from scratch.
    fn wait(&self) -> Option<SharedOp> {
        let mut st = lock_mutex(&self.state);
        loop {
            match &*st {
                LatchState::Pending => {
                    st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
                }
                LatchState::Ready(op) => return Some(Arc::clone(op)),
                LatchState::Poisoned => return None,
            }
        }
    }
}

struct Shard {
    entries: HashMap<OpKey, Entry>,
    /// Keys whose build is currently running outside the lock.
    inflight: HashMap<OpKey, Arc<Latch>>,
}

/// Sharded, lock-striped LRU map from [`OpKey`] to a shared operator.
/// All methods take `&self`; the registry is safe to share behind an
/// `Arc` across any number of serving threads.
pub struct Registry {
    shards: Vec<RwLock<Shard>>,
    shard_capacity: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
    build_nanos: AtomicU64,
}

/// Recover a mutex guard even if another thread panicked while holding
/// it. The registry's invariants hold at every await/unlock point (state
/// transitions are single assignments), so a poisoned lock carries no
/// torn state worth propagating — and a serving process must not let one
/// bad request wedge the cache for every tenant.
fn lock_mutex<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn lock_read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

fn lock_write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

/// Removes the in-flight latch and poisons it if the build panics, so
/// coalesced waiters wake up and retry instead of blocking forever.
struct BuildGuard<'a> {
    shard: &'a RwLock<Shard>,
    key: OpKey,
    latch: Arc<Latch>,
    done: bool,
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            lock_write(self.shard).inflight.remove(&self.key);
            self.latch.poison();
        }
    }
}

impl Registry {
    /// Empty registry holding at most `capacity` operators (min 1),
    /// striped over `min(8, capacity)` shards.
    pub fn new(capacity: usize) -> Registry {
        let capacity = capacity.max(1);
        Registry::with_shards(capacity, capacity.min(8))
    }

    /// Explicit shard count — `with_shards(cap, 1)` gives the exact
    /// single-map LRU semantics the eviction unit tests rely on. Each
    /// shard holds at most `floor(capacity / nshards)` entries (min 1),
    /// so the total population never exceeds `capacity`.
    pub fn with_shards(capacity: usize, nshards: usize) -> Registry {
        let capacity = capacity.max(1);
        let nshards = nshards.clamp(1, capacity);
        let shards = (0..nshards)
            .map(|_| {
                RwLock::new(Shard { entries: HashMap::new(), inflight: HashMap::new() })
            })
            .collect();
        Registry {
            shards,
            shard_capacity: (capacity / nshards).max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            build_nanos: AtomicU64::new(0),
        }
    }

    fn shard_for(&self, key: &OpKey) -> &RwLock<Shard> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up `key`, building (and caching) the operator on a miss.
    /// Returns a clone of the cached Arc — repeated calls with the same
    /// key return pointer-equal operators until the entry is evicted.
    ///
    /// Concurrent semantics: a hit holds only the shard's read lock; a
    /// miss registers an in-flight latch under the write lock and then
    /// builds with **no** lock held, so hits (and other shards) are never
    /// blocked behind a build. Concurrent misses on the same key wait on
    /// the first thread's latch and share its operator; if that build
    /// panics they retry, and one of them becomes the new builder.
    pub fn get_or_build(&self, key: OpKey, build: impl FnOnce() -> SharedOp) -> SharedOp {
        // Each caller owns one builder closure; it is consumed at most
        // once (a caller that becomes the builder returns immediately
        // after, or propagates the build's panic).
        let mut build = Some(build);
        let shard = self.shard_for(&key);
        loop {
            // Fast path: shared read lock, atomic recency stamp.
            {
                let guard = lock_read(shard);
                if let Some(entry) = guard.entries.get(&key) {
                    entry.last_used.store(self.next_tick(), Ordering::Relaxed);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Arc::clone(&entry.op);
                }
            }
            // Slow path: re-check under the write lock (another thread
            // may have inserted between our read unlock and here).
            let latch = {
                let mut guard = lock_write(shard);
                if let Some(entry) = guard.entries.get(&key) {
                    entry.last_used.store(self.next_tick(), Ordering::Relaxed);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Arc::clone(&entry.op);
                }
                if let Some(latch) = guard.inflight.get(&key) {
                    // Someone else is already building this key: wait on
                    // their latch with no shard lock held.
                    let latch = Arc::clone(latch);
                    drop(guard);
                    self.coalesced.fetch_add(1, Ordering::Relaxed);
                    match latch.wait() {
                        Some(op) => return op,
                        None => continue, // builder panicked — retry
                    }
                }
                let latch = Arc::new(Latch::new());
                guard.inflight.insert(key, Arc::clone(&latch));
                self.misses.fetch_add(1, Ordering::Relaxed);
                latch
            };
            // We are the builder. Run the (possibly O(N log N)) build
            // with no shard lock held; the guard poisons the latch if
            // the build panics so waiters are not stranded.
            let mut guard = BuildGuard { shard, key, latch, done: false };
            let t0 = std::time::Instant::now();
            let op = build.take().expect("builder closure consumed once")();
            self.build_nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            {
                let mut sg = lock_write(shard);
                sg.inflight.remove(&key);
                // Evict least-recently-used entries until the newcomer
                // fits inside this shard's slice of the capacity.
                while sg.entries.len() >= self.shard_capacity {
                    let oldest = sg
                        .entries
                        .iter()
                        .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                        .map(|(k, _)| *k)
                        .expect("non-empty shard");
                    sg.entries.remove(&oldest);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                sg.entries.insert(
                    key,
                    Entry { op: Arc::clone(&op), last_used: AtomicU64::new(self.next_tick()) },
                );
            }
            guard.done = true;
            guard.latch.fulfill(Arc::clone(&op));
            return op;
        }
    }

    /// Counter snapshot. Individual counters are read with relaxed
    /// ordering — the snapshot is monotone but not a single atomic cut
    /// across counters, which is fine for the observability it serves.
    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            build_seconds: self.build_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            len: self.shards.iter().map(|s| lock_read(s).entries.len()).sum(),
        }
    }

    /// Drop every cached operator (counters are preserved; in-flight
    /// builds are left to complete and insert normally).
    pub fn clear(&self) {
        for shard in &self.shards {
            lock_write(shard).entries.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::DenseOperator;
    use crate::kernels::Kernel;
    use crate::rng::Pcg32;
    use std::sync::atomic::AtomicUsize;

    fn key(src_fp: u128) -> OpKey {
        OpKey {
            src_fp,
            tgt_fp: None,
            family: Family::Gaussian,
            scale_bits: 1.0f64.to_bits(),
            p: 4,
            theta_bits: 0.5f64.to_bits(),
            leaf_capacity: 64,
            center: ExpansionCenter::BoxCenter,
            compression: false,
            panel_budget: crate::fkt::DEFAULT_PANEL_BUDGET_BYTES,
            precision: Precision::F64,
            dense: false,
            composite: false,
        }
    }

    fn tiny_op() -> SharedOp {
        let pts = Points::new(2, vec![0.0, 0.0, 1.0, 1.0]);
        Arc::new(DenseOperator::square(&pts, Kernel::canonical(Family::Gaussian)))
    }

    #[test]
    fn fingerprint_is_coordinate_sensitive() {
        let mut rng = Pcg32::seeded(601);
        let a = Points::new(3, rng.uniform_vec(60, 0.0, 1.0));
        let mut b = a.clone();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        b.point_mut(7)[1] += 1e-14;
        assert_ne!(fingerprint(&a), fingerprint(&b), "single-coordinate perturbation");
        // Dimension is part of the identity even with identical buffers.
        let c = Points::new(2, a.coords.clone());
        assert_ne!(fingerprint(&a), fingerprint(&c));
    }

    #[test]
    fn projection_fingerprint_is_stable_and_axis_sensitive() {
        let parent = fingerprint(&Points::new(3, vec![0.5; 9]));
        let a = projection_fingerprint(parent, &[0, 2]);
        assert_eq!(a, projection_fingerprint(parent, &[0, 2]), "deterministic");
        assert_ne!(a, projection_fingerprint(parent, &[0, 1]), "axis-sensitive");
        assert_ne!(a, projection_fingerprint(parent, &[2, 0]), "order-sensitive");
        assert_ne!(a, projection_fingerprint(parent ^ 1, &[0, 2]), "parent-sensitive");
        assert_ne!(a, parent, "domain-separated from dataset fingerprints");
    }

    #[test]
    fn composite_fingerprint_is_a_multiset() {
        let (ka, kb) = (key(1), key(2));
        let ab = composite_fingerprint(&[(1.0, ka), (2.0, kb)]);
        let ba = composite_fingerprint(&[(2.0, kb), (1.0, ka)]);
        assert_eq!(ab, ba, "term order must not matter");
        assert_ne!(
            ab,
            composite_fingerprint(&[(2.0, ka), (1.0, kb)]),
            "weights bind to their terms"
        );
        assert_ne!(
            ab,
            composite_fingerprint(&[(1.0, ka), (2.0, kb), (1.0, ka)]),
            "multiplicity matters"
        );
    }

    #[test]
    fn hits_return_pointer_equal_arcs() {
        let reg = Registry::new(8);
        let first = reg.get_or_build(key(1), tiny_op);
        let second = reg.get_or_build(key(1), || panic!("must not rebuild"));
        assert!(Arc::ptr_eq(&first, &second));
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 1, 1));
    }

    #[test]
    fn distinct_keys_build_distinct_operators() {
        let reg = Registry::new(8);
        let a = reg.get_or_build(key(1), tiny_op);
        let b = reg.get_or_build(key(2), tiny_op);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(reg.stats().misses, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        // Single shard so eviction order is exact, not per-stripe.
        let reg = Registry::with_shards(2, 1);
        let a = reg.get_or_build(key(1), tiny_op);
        let _b = reg.get_or_build(key(2), tiny_op);
        // Touch key 1 so key 2 is the LRU entry.
        let a2 = reg.get_or_build(key(1), || panic!("cached"));
        assert!(Arc::ptr_eq(&a, &a2));
        let _c = reg.get_or_build(key(3), tiny_op); // evicts key 2
        let s = reg.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.len, 2);
        // Key 1 survived; key 2 was evicted and must rebuild.
        let a3 = reg.get_or_build(key(1), || panic!("cached"));
        assert!(Arc::ptr_eq(&a, &a3));
        let rebuilt = std::cell::Cell::new(false);
        let _b2 = reg.get_or_build(key(2), || {
            rebuilt.set(true);
            tiny_op()
        });
        assert!(rebuilt.get(), "evicted entry must rebuild");
    }

    #[test]
    fn build_time_is_accounted() {
        let reg = Registry::new(4);
        let _ = reg.get_or_build(key(9), || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            tiny_op()
        });
        assert!(reg.stats().build_seconds > 0.0);
    }

    #[test]
    fn concurrent_misses_on_one_key_build_once() {
        const THREADS: usize = 8;
        let reg = Registry::new(8);
        let builds = AtomicUsize::new(0);
        let ptrs: Vec<usize> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    scope.spawn(|| {
                        let op = reg.get_or_build(key(7), || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // Long enough that the other threads arrive
                            // while the build is still in flight.
                            std::thread::sleep(std::time::Duration::from_millis(30));
                            tiny_op()
                        });
                        Arc::as_ptr(&op) as *const () as usize
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "exactly one build");
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "all threads share one Arc");
        let s = reg.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(
            s.hits + s.coalesced,
            THREADS as u64 - 1,
            "losers either coalesced onto the latch or hit the fresh entry"
        );
    }

    #[test]
    fn poisoned_build_unblocks_waiters_who_then_rebuild() {
        let reg = Registry::new(8);
        let builds = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            // Builder: registers the latch, then panics mid-build.
            let bad = scope.spawn(|| {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    reg.get_or_build(key(3), || {
                        std::thread::sleep(std::time::Duration::from_millis(30));
                        panic!("injected build failure");
                    })
                }));
                assert!(r.is_err(), "builder's panic propagates to its caller");
            });
            // Waiter: arrives while the doomed build is in flight, waits
            // on the latch, observes the poison, retries, and becomes
            // the new builder.
            let good = scope.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(10));
                reg.get_or_build(key(3), || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    tiny_op()
                })
            });
            bad.join().unwrap();
            let op = good.join().unwrap();
            assert_eq!(op.num_sources(), 2);
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "waiter rebuilt after poison");
        // Both the doomed and the successful attempt were misses.
        assert_eq!(reg.stats().misses, 2);
        // The entry is cached normally afterwards.
        let _ = reg.get_or_build(key(3), || panic!("cached"));
    }

    #[test]
    fn stress_counters_balance_and_capacity_holds() {
        const THREADS: usize = 8;
        const ROUNDS: usize = 40;
        const KEYSPACE: u128 = 12;
        const CAPACITY: usize = 6;
        let reg = Registry::new(CAPACITY);
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let reg = &reg;
                scope.spawn(move || {
                    for r in 0..ROUNDS {
                        let k = ((t * 31 + r * 7) as u128) % KEYSPACE;
                        let op = reg.get_or_build(key(k), tiny_op);
                        assert_eq!(op.num_sources(), 2);
                    }
                });
            }
        });
        let s = reg.stats();
        assert_eq!(
            s.hits + s.misses + s.coalesced,
            (THREADS * ROUNDS) as u64,
            "every request is exactly one of hit / miss / coalesced"
        );
        assert!(s.len <= CAPACITY, "population {} exceeds capacity {}", s.len, CAPACITY);
        assert_eq!(s.evictions, s.misses - s.len as u64, "every miss is cached or evicted");
    }

    #[test]
    fn hot_keys_stay_pointer_equal_across_threads() {
        const THREADS: usize = 8;
        let reg = Registry::new(8);
        // Warm four keys so every thread should hit.
        let warm: Vec<usize> = (0..4)
            .map(|k| Arc::as_ptr(&reg.get_or_build(key(k as u128), tiny_op)) as *const () as usize)
            .collect();
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                let reg = &reg;
                let warm = &warm;
                scope.spawn(move || {
                    for round in 0..20 {
                        let k = round % 4;
                        let op = reg.get_or_build(key(k as u128), || panic!("must hit"));
                        assert_eq!(Arc::as_ptr(&op) as *const () as usize, warm[k]);
                    }
                });
            }
        });
        assert_eq!(reg.stats().misses, 4);
    }
}
