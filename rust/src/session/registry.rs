//! The session's keyed operator registry.
//!
//! An FKT operator is expensive to build (tree + interaction plan + exact
//! expansion coefficients) but cheap to *reuse* — the whole point of a
//! service handling many requests over the same dataset. The registry maps
//! a structural key — dataset fingerprint(s) × kernel × fully resolved
//! configuration — to a cached `Arc<dyn KernelOp>`, so a repeated request
//! returns the *same* operator (pointer-equal Arc) without rebuilding.
//!
//! **Fingerprinting.** Datasets have no identity of their own (`Points` is
//! a plain coordinate buffer), so the registry derives one: two
//! independent word-wise hash lanes (128 bits total) over `(d, n, every
//! coordinate's f64 bit pattern)`. Any change to any coordinate changes
//! the fingerprint, so a moving dataset (t-SNE's per-iteration embedding)
//! naturally misses the cache while a static dataset (a GP's training
//! set) always hits it. The fingerprint is *probabilistic* identity: an
//! accidental collision (≈2⁻¹²⁸ for unrelated data) would serve the wrong
//! operator, and the hash is non-cryptographic — adversarially crafted
//! point sets are out of scope for this cache.
//!
//! **Eviction.** Bounded LRU: every hit/insert stamps a monotone tick, and
//! inserting past capacity evicts the least-recently-used entry. Workloads
//! that churn operators (t-SNE rebuilds two per gradient step) therefore
//! hold memory constant instead of accumulating dead trees.

use crate::fkt::ExpansionCenter;
use crate::kernels::Family;
use crate::linalg::Precision;
use crate::op::KernelOp;
use crate::points::Points;
use std::collections::HashMap;
use std::sync::Arc;

/// Two-lane word-wise hash over an arbitrary u64 word stream. Lane 1 is
/// FNV-1a (xor-then-multiply); lane 2 multiplies first and folds in a
/// rotated word, so the lanes don't share collision structure. Two
/// multiplies per word keep the hash far cheaper than the work it guards.
/// This is the one hashing scheme behind every cache identity in the crate
/// — the registry's dataset [`fingerprint`] and the GP's representer-
/// weight `y`-fingerprint both feed it — so its mixing evolves in exactly
/// one place. See the module docs for what this probabilistic identity
/// does and does not guarantee.
pub fn fingerprint_words(words: impl IntoIterator<Item = u64>) -> u128 {
    const OFFSET1: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME1: u64 = 0x0000_0100_0000_01b3;
    const OFFSET2: u64 = 0x6c62_272e_07bb_0142;
    const PRIME2: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut h1 = OFFSET1;
    let mut h2 = OFFSET2;
    for word in words {
        h1 = (h1 ^ word).wrapping_mul(PRIME1);
        h2 = h2.wrapping_mul(PRIME2) ^ word.rotate_left(32);
    }
    ((h1 as u128) << 64) | h2 as u128
}

/// Dataset fingerprint: dimension, count, and the bit pattern of every
/// coordinate through [`fingerprint_words`].
pub fn fingerprint(points: &Points) -> u128 {
    fingerprint_words(
        [points.d as u64, points.len() as u64]
            .into_iter()
            .chain(points.coords.iter().map(|c| c.to_bits())),
    )
}

/// Structural identity of one operator request. Configuration fields are
/// exact (floating-point parameters are keyed by bit pattern, not by
/// value); dataset identity is the 128-bit [`fingerprint`], so equal keys
/// build identical operators up to that fingerprint's collision bound.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OpKey {
    /// Source-dataset fingerprint.
    pub src_fp: u128,
    /// Target-dataset fingerprint; `None` for the square case.
    pub tgt_fp: Option<u128>,
    /// Kernel family.
    pub family: Family,
    /// Kernel coordinate scale (bit pattern).
    pub scale_bits: u64,
    /// Resolved truncation order p.
    pub p: usize,
    /// Resolved separation parameter θ (bit pattern).
    pub theta_bits: u64,
    /// Leaf capacity.
    pub leaf_capacity: usize,
    /// Expansion-center convention.
    pub center: ExpansionCenter,
    /// §A.4 compression toggle.
    pub compression: bool,
    /// Far-field panel-cache byte budget (`FktConfig::panel_budget_bytes`)
    /// — part of the identity because it changes the built operator's
    /// memory footprint and apply-time behavior.
    pub panel_budget: usize,
    /// Resolved storage-precision tier (`Auto` never appears here — the
    /// session resolves it before keying): the same spec at f32 and f64 is
    /// two distinct operators with different panel storage, residency, and
    /// error floor, while an `Auto` request that resolves to a tier shares
    /// that tier's cache entry.
    pub precision: Precision,
    /// Exact dense backend instead of the FKT.
    pub dense: bool,
}

/// Registry counters — the observable behaviour of the cache. `hits` vs
/// `misses` is asserted in tests; `build_seconds` accumulates the time the
/// cache has *saved callers from paying again*.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegistryStats {
    /// Requests answered from the cache.
    pub hits: u64,
    /// Requests that had to build a new operator.
    pub misses: u64,
    /// Entries evicted by the LRU policy.
    pub evictions: u64,
    /// Total seconds spent building operators (misses only).
    pub build_seconds: f64,
    /// Current number of cached operators.
    pub len: usize,
}

struct Entry {
    op: Arc<dyn KernelOp + Send + Sync>,
    last_used: u64,
}

/// Bounded LRU map from [`OpKey`] to a shared operator.
pub struct Registry {
    entries: HashMap<OpKey, Entry>,
    capacity: usize,
    tick: u64,
    stats: RegistryStats,
}

impl Registry {
    /// Empty registry holding at most `capacity` operators (min 1).
    pub fn new(capacity: usize) -> Registry {
        Registry {
            entries: HashMap::new(),
            capacity: capacity.max(1),
            tick: 0,
            stats: RegistryStats::default(),
        }
    }

    /// Look up `key`, building (and caching) the operator on a miss.
    /// Returns a clone of the cached Arc — repeated calls with the same
    /// key return pointer-equal operators until the entry is evicted.
    pub fn get_or_build(
        &mut self,
        key: OpKey,
        build: impl FnOnce() -> Arc<dyn KernelOp + Send + Sync>,
    ) -> Arc<dyn KernelOp + Send + Sync> {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(&key) {
            entry.last_used = self.tick;
            self.stats.hits += 1;
            self.stats.len = self.entries.len();
            return Arc::clone(&entry.op);
        }
        self.stats.misses += 1;
        let t0 = std::time::Instant::now();
        let op = build();
        self.stats.build_seconds += t0.elapsed().as_secs_f64();
        // Evict least-recently-used entries until the newcomer fits.
        while self.entries.len() >= self.capacity {
            let oldest = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("non-empty registry");
            self.entries.remove(&oldest);
            self.stats.evictions += 1;
        }
        self.entries.insert(key, Entry { op: Arc::clone(&op), last_used: self.tick });
        self.stats.len = self.entries.len();
        op
    }

    /// Counter snapshot.
    pub fn stats(&self) -> RegistryStats {
        self.stats
    }

    /// Drop every cached operator (counters are preserved).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.stats.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::DenseOperator;
    use crate::kernels::Kernel;
    use crate::rng::Pcg32;

    fn key(src_fp: u128) -> OpKey {
        OpKey {
            src_fp,
            tgt_fp: None,
            family: Family::Gaussian,
            scale_bits: 1.0f64.to_bits(),
            p: 4,
            theta_bits: 0.5f64.to_bits(),
            leaf_capacity: 64,
            center: ExpansionCenter::BoxCenter,
            compression: false,
            panel_budget: crate::fkt::DEFAULT_PANEL_BUDGET_BYTES,
            precision: Precision::F64,
            dense: false,
        }
    }

    fn tiny_op() -> Arc<dyn KernelOp + Send + Sync> {
        let pts = Points::new(2, vec![0.0, 0.0, 1.0, 1.0]);
        Arc::new(DenseOperator::square(&pts, Kernel::canonical(Family::Gaussian)))
    }

    #[test]
    fn fingerprint_is_coordinate_sensitive() {
        let mut rng = Pcg32::seeded(601);
        let a = Points::new(3, rng.uniform_vec(60, 0.0, 1.0));
        let mut b = a.clone();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        b.point_mut(7)[1] += 1e-14;
        assert_ne!(fingerprint(&a), fingerprint(&b), "single-coordinate perturbation");
        // Dimension is part of the identity even with identical buffers.
        let c = Points::new(2, a.coords.clone());
        assert_ne!(fingerprint(&a), fingerprint(&c));
    }

    #[test]
    fn hits_return_pointer_equal_arcs() {
        let mut reg = Registry::new(8);
        let first = reg.get_or_build(key(1), tiny_op);
        let second = reg.get_or_build(key(1), || panic!("must not rebuild"));
        assert!(Arc::ptr_eq(&first, &second));
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 1, 1));
    }

    #[test]
    fn distinct_keys_build_distinct_operators() {
        let mut reg = Registry::new(8);
        let a = reg.get_or_build(key(1), tiny_op);
        let b = reg.get_or_build(key(2), tiny_op);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(reg.stats().misses, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut reg = Registry::new(2);
        let a = reg.get_or_build(key(1), tiny_op);
        let _b = reg.get_or_build(key(2), tiny_op);
        // Touch key 1 so key 2 is the LRU entry.
        let a2 = reg.get_or_build(key(1), || panic!("cached"));
        assert!(Arc::ptr_eq(&a, &a2));
        let _c = reg.get_or_build(key(3), tiny_op); // evicts key 2
        let s = reg.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.len, 2);
        // Key 1 survived; key 2 was evicted and must rebuild.
        let a3 = reg.get_or_build(key(1), || panic!("cached"));
        assert!(Arc::ptr_eq(&a, &a3));
        let mut rebuilt = false;
        let _b2 = reg.get_or_build(key(2), || {
            rebuilt = true;
            tiny_op()
        });
        assert!(rebuilt, "evicted entry must rebuild");
    }

    #[test]
    fn build_time_is_accounted() {
        let mut reg = Registry::new(4);
        let _ = reg.get_or_build(key(9), || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            tiny_op()
        });
        assert!(reg.stats().build_seconds > 0.0);
    }
}
