//! Tolerance-driven resolution of the FKT hyperparameters.
//!
//! The paper's headline property is accuracy that is "high, quantifiable,
//! and controllable" — controllable through the Lemma 4.1 truncation bound,
//! which upper-bounds the error of every far-field interaction at
//! truncation order `p` when sources satisfy the separation criterion
//! `r' ≤ θ·r`. This module inverts that bound: given a requested tolerance
//! ε it scans a candidate grid of `(p, θ)` pairs, keeps those whose bound
//! estimate is ≤ ε, and returns the one with the cheapest predicted
//! runtime. The session calls it whenever an operator request carries
//! `.tolerance(ε)` instead of explicit hyperparameters.
//!
//! **Protocol.** For each candidate θ the bound is evaluated with ratio
//! `r'/r = θ` (the worst separation the interaction plan admits) and
//! maximized over a deterministic log-spaced radius grid covering the
//! *dataset's* scaled diameter — the bound is data-aware: compact datasets
//! resolve cheaper configurations than sprawling ones. This mirrors the
//! paper's Fig 2-right protocol (fixed ratio, max over r) with the paper's
//! arbitrary `r ∈ (0, 20]` replaced by the radii the operator will
//! actually encounter.
//!
//! **Cost model.** Far-field work per (node, target) pair is proportional
//! to the number of multipole terms `𝒫 = C(p+d, d)`; shrinking θ trades
//! far-field pairs for near-field pairs roughly like `(1/θ)^d`. The
//! resolver ranks feasible pairs by `𝒫(p) · (θ_ref/θ)^d` with
//! `θ_ref = 0.75` (the library default), which prefers the loosest
//! separation that still meets ε and only tightens θ when the order cap
//! would otherwise be exceeded.

use crate::expansion::bound::truncation_bound_at;
use crate::expansion::{CoeffTable, Expansion};
use crate::kernels::Kernel;
use crate::linalg::Precision;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Process-wide cache of exact-rational coefficient tables keyed by
/// `(d, order)` — the one genuinely expensive input to a bound scan, and
/// identical across every session/resolution that shares a dimension.
fn shared_table(d: usize, jmax: usize) -> Arc<CoeffTable> {
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize), Arc<CoeffTable>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("coeff-table cache poisoned");
    Arc::clone(
        guard
            .entry((d, jmax))
            .or_insert_with(|| Arc::new(CoeffTable::build(d, jmax))),
    )
}

/// Separation-parameter candidates, loosest (cheapest near field) first.
pub const THETA_CANDIDATES: [f64; 7] = [0.75, 0.6, 0.5, 0.4, 0.3, 0.25, 0.2];

/// Radii sampled per bound estimate.
const N_RADII: usize = 24;

/// The per-interaction bound is enforced at `ε × SAFETY`, not ε. Lemma
/// 4.1 bounds each *pairwise* truncated kernel value; the aggregate MVM
/// error a caller measures accumulates those per-pair errors across a
/// target's far sources, partially cancelling but not bounded by ε
/// per se. Empirically the bound already sits ~10–30× above measured
/// MVM error (it maximizes over the worst radius at the worst admissible
/// separation); the 4× margin buys additional headroom for accumulation
/// so `.tolerance(ε)` keeps its measured-error promise.
const SAFETY: f64 = 0.25;

/// Smallest requested tolerance for which [`auto_precision`] selects f32
/// storage. The ε/4 headroom rule (`SAFETY`) reserves the caller's ε for
/// truncation *plus* accumulation effects; extending it to cover storage
/// rounding, the f32 tier's contribution — coefficient/kernel-value
/// rounding of ≈2⁻²⁴ ≈ 6e-8 relative per stored value, amplified by
/// partial cancellation to the order of 1e-6 in aggregate (measured ≲1e-6
/// across the tested kernels, asserted ≤5e-6) — must itself sit below
/// ε·SAFETY. That holds with ≥10× margin once ε·SAFETY ≥ 2.5e-6, i.e.
/// ε ≥ 1e-5; below that the resolver must keep full f64 storage.
pub const F32_AUTO_MIN_EPS: f64 = 1e-5;

/// Resolve [`Precision::Auto`] for a request: f32 storage when the
/// requested ε leaves headroom above f32 round-off (see
/// [`F32_AUTO_MIN_EPS`]), f64 otherwise — including when no tolerance was
/// requested at all (explicit `(p, θ)` states no error budget the resolver
/// could spend on storage rounding, so it stays conservative).
pub fn auto_precision(tolerance: Option<f64>) -> Precision {
    match tolerance {
        Some(eps) if eps >= F32_AUTO_MIN_EPS => Precision::F32,
        _ => Precision::F64,
    }
}

/// Extra tail orders kept beyond the largest candidate p when summing the
/// Lemma 4.1 tail (the paper sums to 30; the tail decays geometrically in
/// θ so six orders bound the remainder well below any ε we accept).
const TAIL_ORDERS: usize = 6;

/// Largest truncation order the resolver will pick, by dimension — caps
/// the per-node term count `C(p+d, d)` at a few hundred so an auto-tuned
/// operator can never be pathologically expensive to build or apply.
pub fn max_order(d: usize) -> usize {
    match d {
        0..=3 => 14,
        4 => 10,
        5 => 8,
        _ => 6,
    }
}

/// ε-splitting policy for additive (composite) operators: a composite of
/// `terms` low-dimensional operators meets a requested aggregate tolerance
/// ε when every term meets ε/terms — the triangle inequality over the sum,
/// with each term's own [`SAFETY`] headroom then applied on top by
/// [`resolve`]. Uniform splitting is deliberately simple: terms share one
/// kernel family and similar projected diameters, so a weighted split
/// would buy little against its added key-fragmentation cost (every
/// distinct per-term ε is a distinct registry key).
pub fn split_tolerance(eps: f64, terms: usize) -> f64 {
    assert!(terms > 0, "tolerance split needs at least one term");
    eps / terms as f64
}

/// One resolved configuration.
#[derive(Clone, Copy, Debug)]
pub struct Resolved {
    /// Truncation order.
    pub p: usize,
    /// Separation parameter.
    pub theta: f64,
    /// The Lemma 4.1 bound estimate the pair achieved (≤ the requested ε).
    pub bound: f64,
}

/// Worst-case bound for `(p, theta)` over the log-spaced radius grid.
fn worst_bound(
    table: &CoeffTable,
    kernel: &Kernel,
    p: usize,
    theta: f64,
    r_lo: f64,
    r_hi: f64,
) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..N_RADII {
        let t = i as f64 / (N_RADII - 1) as f64;
        let r = r_lo * (r_hi / r_lo).powf(t);
        worst = worst.max(truncation_bound_at(table, kernel, p, r, theta));
    }
    worst
}

/// Resolve `(p, θ)` for a requested tolerance `ε` on a dataset whose
/// *scaled* radii span up to `r_max` (kernel length-scales are folded into
/// the coordinates, so `r_max` is the raw diameter times `kernel.scale`).
///
/// Returns `None` when no candidate pair within [`max_order`] meets ε —
/// callers should surface that as "tolerance unattainable; pass explicit
/// `.order(p)`/`.theta(t)`".
pub fn resolve(kernel: &Kernel, d: usize, eps: f64, r_max: f64) -> Option<Resolved> {
    assert!(eps > 0.0, "tolerance must be positive");
    assert!(eps.is_finite());
    // Headroom for per-pair → aggregate error accumulation (see SAFETY).
    let eps = eps * SAFETY;
    // The FKT lifts 1-D data into the plane; the bound follows suit.
    let d = d.max(2);
    let p_max = max_order(d);
    // Table order = largest p + the tail orders summed beyond it. Built in
    // exact rational arithmetic once per (d, order) process-wide; sessions
    // additionally cache whole resolutions, so this is paid per distinct
    // request shape, not per operator build.
    let jmax = p_max + TAIL_ORDERS;
    let table = shared_table(d, jmax);
    // Degenerate/absurd diameters fall back to the paper's r ∈ (0, 20]
    // protocol ceiling.
    let r_hi = if r_max.is_finite() && r_max > 0.0 { r_max.min(20.0) } else { 1.0 };
    // Singular kernels blow the bound up trivially as r → 0 (so would the
    // kernel itself); keep the scan off the singularity.
    let r_lo = r_hi * if kernel.family.singular_at_origin() { 5e-2 } else { 1e-3 };
    let theta_ref = 0.75f64;
    let mut best: Option<(f64, Resolved)> = None;
    for &theta in THETA_CANDIDATES.iter() {
        for p in 0..=p_max {
            let b = worst_bound(&table, kernel, p, theta, r_lo, r_hi);
            if b.is_nan() || b > eps {
                continue;
            }
            let cost = Expansion::expected_num_terms(d, p) as f64
                * (theta_ref / theta).powi(d as i32);
            if best.as_ref().map(|(c, _)| cost < *c).unwrap_or(true) {
                best = Some((cost, Resolved { p, theta, bound: b }));
            }
            break; // smallest feasible p for this θ; larger p only costs more
        }
    }
    best.map(|(_, r)| r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Family;

    #[test]
    fn tighter_tolerance_needs_higher_order() {
        let kern = Kernel::canonical(Family::Gaussian);
        let loose = resolve(&kern, 2, 1e-2, 1.5).expect("1e-2 attainable");
        let tight = resolve(&kern, 2, 1e-5, 1.5).expect("1e-5 attainable");
        assert!(loose.bound <= 1e-2);
        assert!(tight.bound <= 1e-5);
        // More accuracy must cost more terms and/or a tighter θ.
        assert!(
            tight.p > loose.p || tight.theta < loose.theta,
            "loose {loose:?} vs tight {tight:?}"
        );
    }

    #[test]
    fn resolved_bound_meets_epsilon_across_kernels() {
        for fam in [Family::Gaussian, Family::Matern52, Family::Cauchy, Family::Exponential] {
            let kern = Kernel::canonical(fam);
            for eps in [1e-2, 1e-4, 1e-6] {
                let r = resolve(&kern, 2, eps, 1.5)
                    .unwrap_or_else(|| panic!("{fam:?} eps={eps} unattainable"));
                assert!(r.bound <= eps, "{fam:?} eps={eps}: bound {}", r.bound);
                assert!(r.p <= max_order(2));
                assert!(THETA_CANDIDATES.contains(&r.theta));
            }
        }
    }

    #[test]
    fn compact_datasets_resolve_cheaper_or_equal() {
        // A smaller scaled diameter can only shrink the bound, so the
        // resolved order at fixed θ ranking never worsens.
        let kern = Kernel::canonical(Family::Cauchy);
        let small = resolve(&kern, 3, 1e-4, 0.5).expect("attainable");
        let large = resolve(&kern, 3, 1e-4, 3.5).expect("attainable");
        let cost = |r: &Resolved| {
            Expansion::expected_num_terms(3, r.p) as f64 * (0.75 / r.theta).powi(3)
        };
        assert!(cost(&small) <= cost(&large), "small {small:?} vs large {large:?}");
    }

    #[test]
    fn auto_precision_rule() {
        // Loose tolerances leave headroom above f32 round-off.
        assert_eq!(auto_precision(Some(1e-2)), Precision::F32);
        assert_eq!(auto_precision(Some(1e-4)), Precision::F32);
        // The boundary is inclusive at ε = 1e-5…
        assert_eq!(auto_precision(Some(F32_AUTO_MIN_EPS)), Precision::F32);
        // …and Auto must NEVER pick f32 below it.
        assert_eq!(auto_precision(Some(9.9e-6)), Precision::F64);
        assert_eq!(auto_precision(Some(1e-6)), Precision::F64);
        assert_eq!(auto_precision(Some(1e-12)), Precision::F64);
        // No tolerance requested ⇒ no budget to spend ⇒ f64.
        assert_eq!(auto_precision(None), Precision::F64);
    }

    #[test]
    fn split_tolerance_is_uniform() {
        assert_eq!(split_tolerance(1e-2, 4), 2.5e-3);
        assert_eq!(split_tolerance(1e-4, 1), 1e-4);
    }

    #[test]
    fn unattainable_tolerance_returns_none() {
        let kern = Kernel::canonical(Family::Gaussian);
        // d = 6 caps p at 6; 1e-12 on a wide dataset is out of reach.
        assert!(resolve(&kern, 6, 1e-12, 10.0).is_none());
    }

    #[test]
    fn matern52_tolerance_chain_stays_feasible() {
        let kern = Kernel::canonical(Family::Matern52);
        for eps in [1e-1, 1e-3, 1e-5, 1e-7] {
            let r = resolve(&kern, 3, eps, 1.8).expect("attainable");
            assert!(r.bound <= eps, "eps={eps}: bound {}", r.bound);
        }
    }
}
