//! Symbolic Laurent polynomials over exact rationals.
//!
//! The §A.4 compression applies to kernels satisfying `K'(r) = q(r) K(r)`
//! with `q` a Laurent polynomial — equivalently `K(r) = L(r)·exp(s(r))` with
//! `L`, `s` Laurent. Differentiating such kernels symbolically keeps every
//! coefficient rational, which is what makes the rank-revealing QR of the
//! radial coefficient matrix *exact* and the recovered ranks `R_k`
//! certificates rather than numerical guesses (paper Tables 2 & 3).

use crate::exact::Rational;
use std::collections::BTreeMap;
use std::fmt;

/// Laurent polynomial `Σ_e c_e r^e`, exponents possibly negative.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Laurent {
    /// exponent → nonzero coefficient.
    terms: BTreeMap<i64, Rational>,
}

impl Laurent {
    /// The zero polynomial.
    pub fn zero() -> Self {
        Laurent { terms: BTreeMap::new() }
    }

    /// The constant 1.
    pub fn one() -> Self {
        Laurent::monomial(Rational::one(), 0)
    }

    /// `c · r^e`.
    pub fn monomial(c: Rational, e: i64) -> Self {
        let mut terms = BTreeMap::new();
        if !c.is_zero() {
            terms.insert(e, c);
        }
        Laurent { terms }
    }

    /// Build from (coefficient, exponent) pairs.
    pub fn from_terms(pairs: &[(Rational, i64)]) -> Self {
        let mut out = Laurent::zero();
        for (c, e) in pairs {
            out.add_term(c.clone(), *e);
        }
        out
    }

    /// In-place add of a single term.
    pub fn add_term(&mut self, c: Rational, e: i64) {
        if c.is_zero() {
            return;
        }
        let entry = self.terms.entry(e).or_insert_with(Rational::zero);
        *entry = entry.add(&c);
        if entry.is_zero() {
            self.terms.remove(&e);
        }
    }

    /// True iff identically zero.
    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    /// Number of nonzero terms.
    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }

    /// Iterate (exponent, coefficient), ascending exponent.
    pub fn iter(&self) -> impl Iterator<Item = (i64, &Rational)> {
        self.terms.iter().map(|(&e, c)| (e, c))
    }

    /// Lowest exponent present (None if zero).
    pub fn min_exponent(&self) -> Option<i64> {
        self.terms.keys().next().copied()
    }

    /// Highest exponent present (None if zero).
    pub fn max_exponent(&self) -> Option<i64> {
        self.terms.keys().next_back().copied()
    }

    /// Coefficient of `r^e` (zero if absent).
    pub fn coeff(&self, e: i64) -> Rational {
        self.terms.get(&e).cloned().unwrap_or_else(Rational::zero)
    }

    /// Sum.
    pub fn add(&self, other: &Self) -> Self {
        let mut out = self.clone();
        for (&e, c) in &other.terms {
            out.add_term(c.clone(), e);
        }
        out
    }

    /// Difference.
    pub fn sub(&self, other: &Self) -> Self {
        let mut out = self.clone();
        for (&e, c) in &other.terms {
            out.add_term(c.neg(), e);
        }
        out
    }

    /// Product.
    pub fn mul(&self, other: &Self) -> Self {
        let mut out = Laurent::zero();
        for (&e1, c1) in &self.terms {
            for (&e2, c2) in &other.terms {
                out.add_term(c1.mul(c2), e1 + e2);
            }
        }
        out
    }

    /// Scale by a rational constant.
    pub fn scale(&self, s: &Rational) -> Self {
        if s.is_zero() {
            return Laurent::zero();
        }
        Laurent {
            terms: self.terms.iter().map(|(&e, c)| (e, c.mul(s))).collect(),
        }
    }

    /// Multiply by `r^e`.
    pub fn shift(&self, e: i64) -> Self {
        Laurent {
            terms: self.terms.iter().map(|(&ex, c)| (ex + e, c.clone())).collect(),
        }
    }

    /// Formal derivative d/dr.
    pub fn derivative(&self) -> Self {
        let mut out = Laurent::zero();
        for (&e, c) in &self.terms {
            if e != 0 {
                out.add_term(c.mul(&Rational::from_i64(e)), e - 1);
            }
        }
        out
    }

    /// Evaluate at a positive real r.
    pub fn eval(&self, r: f64) -> f64 {
        let mut acc = 0.0;
        for (&e, c) in &self.terms {
            acc += c.to_f64() * r.powi(e as i32);
        }
        acc
    }

    /// Evaluate using precomputed powers (see [`Laurent::eval`]); powers maps
    /// exponent e → r^e for every exponent present.
    pub fn eval_with(&self, pow: impl Fn(i64) -> f64) -> f64 {
        let mut acc = 0.0;
        for (&e, c) in &self.terms {
            acc += c.to_f64() * pow(e);
        }
        acc
    }
}

impl fmt::Display for Laurent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_zero() {
            return write!(f, "0");
        }
        let mut first = true;
        // Print descending exponent, like the paper's Table 3.
        for (&e, c) in self.terms.iter().rev() {
            let neg = c.is_negative();
            let mag = c.abs();
            if first {
                if neg {
                    write!(f, "-")?;
                }
                first = false;
            } else if neg {
                write!(f, " - ")?;
            } else {
                write!(f, " + ")?;
            }
            let coeff_is_one = mag == Rational::one();
            match (e, coeff_is_one) {
                (0, _) => write!(f, "{mag}")?,
                (1, true) => write!(f, "r")?,
                (1, false) => write!(f, "{mag}*r")?,
                (_, true) => write!(f, "r^{e}")?,
                (_, false) => write!(f, "{mag}*r^{e}")?,
            }
        }
        Ok(())
    }
}

/// A function of the form `L(r) · exp(s(r))` with `L`, `s` Laurent.
///
/// Closed under differentiation: `(L e^s)' = (L' + L s') e^s`. This is the
/// symbolic representation used by the §A.4 compression path; the class
/// covers `1/r^a`, `e^{-r}`, `r e^{-r}`, `e^{-r}/r`, `e^{-r²}` (Gaussian),
/// `e^{-1/r}`, `e^{-1/r²}`, and all Matérn half-integer kernels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExpPoly {
    /// The Laurent prefactor `L(r)`.
    pub prefactor: Laurent,
    /// The Laurent exponent `s(r)`.
    pub exponent: Laurent,
}

impl ExpPoly {
    /// Build `L(r)·exp(s(r))`.
    pub fn new(prefactor: Laurent, exponent: Laurent) -> Self {
        ExpPoly { prefactor, exponent }
    }

    /// Derivative: `(L' + L·s') e^s`.
    pub fn derivative(&self) -> Self {
        ExpPoly {
            prefactor: self
                .prefactor
                .derivative()
                .add(&self.prefactor.mul(&self.exponent.derivative())),
            exponent: self.exponent.clone(),
        }
    }

    /// All derivatives 0..=m as ExpPoly (shared exponent).
    pub fn derivatives(&self, m: usize) -> Vec<Self> {
        let mut out = Vec::with_capacity(m + 1);
        out.push(self.clone());
        for i in 0..m {
            let next = out[i].derivative();
            out.push(next);
        }
        out
    }

    /// Evaluate at r > 0.
    pub fn eval(&self, r: f64) -> f64 {
        self.prefactor.eval(r) * self.exponent.eval(r).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(a: i64, b: i64) -> Rational {
        Rational::ratio(a, b)
    }

    #[test]
    fn construction_cancels_zero_terms() {
        let mut p = Laurent::monomial(r(1, 1), 2);
        p.add_term(r(-1, 1), 2);
        assert!(p.is_zero());
    }

    #[test]
    fn polynomial_product() {
        // (r + 1)(r - 1) = r^2 - 1
        let a = Laurent::from_terms(&[(r(1, 1), 1), (r(1, 1), 0)]);
        let b = Laurent::from_terms(&[(r(1, 1), 1), (r(-1, 1), 0)]);
        let p = a.mul(&b);
        assert_eq!(p.coeff(2), r(1, 1));
        assert_eq!(p.coeff(0), r(-1, 1));
        assert_eq!(p.coeff(1), Rational::zero());
        assert_eq!(p.num_terms(), 2);
    }

    #[test]
    fn laurent_negative_exponents() {
        // (1/r)(1/r) = 1/r^2, and derivative d/dr r^{-2} = -2 r^{-3}
        let invr = Laurent::monomial(r(1, 1), -1);
        let p = invr.mul(&invr);
        assert_eq!(p.coeff(-2), r(1, 1));
        let d = p.derivative();
        assert_eq!(d.coeff(-3), r(-2, 1));
    }

    #[test]
    fn derivative_of_constant_is_zero() {
        assert!(Laurent::one().derivative().is_zero());
    }

    #[test]
    fn eval_matches_f64_poly() {
        // p(r) = 3r^2 - 1/2 r^{-1} + 4
        let p = Laurent::from_terms(&[(r(3, 1), 2), (r(-1, 2), -1), (r(4, 1), 0)]);
        let x = 1.7;
        let expect = 3.0 * x * x - 0.5 / x + 4.0;
        assert!((p.eval(x) - expect).abs() < 1e-14);
    }

    #[test]
    fn exp_poly_derivatives_of_exponential_kernel() {
        // K = e^{-r}: K^(m) = (-1)^m e^{-r}
        let k = ExpPoly::new(Laurent::one(), Laurent::monomial(r(-1, 1), 1));
        let ds = k.derivatives(5);
        for (m, d) in ds.iter().enumerate() {
            let sign = if m % 2 == 0 { r(1, 1) } else { r(-1, 1) };
            assert_eq!(d.prefactor, Laurent::monomial(sign, 0), "m={m}");
        }
    }

    #[test]
    fn exp_poly_derivative_matches_jet() {
        // K = r e^{-2r}; check derivatives against jets numerically.
        let k = ExpPoly::new(
            Laurent::monomial(r(1, 1), 1),
            Laurent::monomial(r(-2, 1), 1),
        );
        let order = 6;
        let r0 = 0.9;
        let x = crate::jet::Jet::variable(r0, order);
        let jet = x.mul(&x.scale(-2.0).exp());
        let ds = k.derivatives(order);
        for m in 0..=order {
            let sym = ds[m].eval(r0);
            let num = jet.derivative(m);
            let scale = 1.0f64.max(num.abs());
            assert!((sym - num).abs() < 1e-10 * scale, "m={m}: {sym} vs {num}");
        }
    }

    #[test]
    fn exp_poly_gaussian_and_inverse_exponent() {
        // K = e^{-r^2}: K' = -2r e^{-r^2};  K = e^{-1/r}: K' = (1/r^2) e^{-1/r}
        let gauss = ExpPoly::new(Laurent::one(), Laurent::monomial(r(-1, 1), 2));
        let d = gauss.derivative();
        assert_eq!(d.prefactor, Laurent::monomial(r(-2, 1), 1));
        let invexp = ExpPoly::new(Laurent::one(), Laurent::monomial(r(-1, 1), -1));
        let d2 = invexp.derivative();
        assert_eq!(d2.prefactor, Laurent::monomial(r(1, 1), -2));
    }

    #[test]
    fn display_is_readable() {
        let p = Laurent::from_terms(&[(r(1, 3), 3), (r(-1, 1), 1), (r(1, 1), 0)]);
        assert_eq!(p.to_string(), "1/3*r^3 - r + 1");
    }
}
