//! Best-first k-nearest-neighbour search on the BSP tree.
//!
//! Fills the role NearestNeighbors.jl plays in the paper's implementation:
//! t-SNE's perplexity calibration needs the `3·perplexity` nearest
//! neighbours of every input point. The search descends the tree
//! best-first, pruning nodes whose box distance exceeds the current k-th
//! best, which is `O(log N)` per query on reasonably distributed data.

use super::Tree;
use crate::linalg::vecops;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Max-heap entry for the running k-best set.
#[derive(PartialEq)]
struct Best {
    dist2: f64,
    idx: usize,
}
impl Eq for Best {}
impl PartialOrd for Best {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Best {
    fn cmp(&self, other: &Self) -> Ordering {
        self.dist2.partial_cmp(&other.dist2).unwrap_or(Ordering::Equal)
    }
}

/// Min-heap entry (via reversed ordering) for the node frontier.
#[derive(PartialEq)]
struct Frontier {
    dist2: f64,
    node: usize,
}
impl Eq for Frontier {}
impl PartialOrd for Frontier {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Frontier {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: smallest distance first.
        other
            .dist2
            .partial_cmp(&self.dist2)
            .unwrap_or(Ordering::Equal)
    }
}

/// Find the k nearest neighbours of `query` among the tree's points.
/// Returns (original index, distance) pairs sorted by increasing distance.
/// `exclude` (an original index) is skipped — pass the query's own index
/// for self-excluding neighbourhoods, or `usize::MAX` for none.
pub fn knn(tree: &Tree, query: &[f64], k: usize, exclude: usize) -> Vec<(usize, f64)> {
    assert_eq!(query.len(), tree.d);
    let mut best: BinaryHeap<Best> = BinaryHeap::with_capacity(k + 1);
    let mut frontier: BinaryHeap<Frontier> = BinaryHeap::new();
    frontier.push(Frontier { dist2: tree.box_dist2(0, query), node: 0 });
    while let Some(Frontier { dist2, node }) = frontier.pop() {
        if best.len() == k && dist2 > best.peek().unwrap().dist2 {
            break; // every remaining node is further than the k-th best
        }
        let nd = &tree.nodes[node];
        match nd.children {
            Some((l, r)) => {
                frontier.push(Frontier { dist2: tree.box_dist2(l, query), node: l });
                frontier.push(Frontier { dist2: tree.box_dist2(r, query), node: r });
            }
            None => {
                for i in nd.start..nd.end {
                    let orig = tree.perm[i];
                    if orig == exclude {
                        continue;
                    }
                    let d2 = vecops::dist2(tree.points.point(i), query);
                    if best.len() < k {
                        best.push(Best { dist2: d2, idx: orig });
                    } else if d2 < best.peek().unwrap().dist2 {
                        best.pop();
                        best.push(Best { dist2: d2, idx: orig });
                    }
                }
            }
        }
    }
    let mut out: Vec<(usize, f64)> = best
        .into_iter()
        .map(|b| (b.idx, b.dist2.sqrt()))
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::Points;
    use crate::rng::Pcg32;

    fn brute_knn(pts: &Points, q: &[f64], k: usize, exclude: usize) -> Vec<(usize, f64)> {
        let mut all: Vec<(usize, f64)> = (0..pts.len())
            .filter(|&i| i != exclude)
            .map(|i| (i, vecops::dist2(pts.point(i), q).sqrt()))
            .collect();
        all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        all.truncate(k);
        all
    }

    #[test]
    fn knn_matches_brute_force() {
        let mut rng = Pcg32::seeded(31);
        for d in [2usize, 3, 6] {
            let n = 400;
            let pts = Points::new(d, rng.uniform_vec(n * d, 0.0, 1.0));
            let tree = Tree::build(&pts, 16);
            for qi in [0usize, 17, 399] {
                let q = pts.point(qi).to_vec();
                let fast = knn(&tree, &q, 10, qi);
                let slow = brute_knn(&pts, &q, 10, qi);
                assert_eq!(fast.len(), 10);
                for (f, s) in fast.iter().zip(&slow) {
                    // Distances must agree; indices may differ under ties.
                    assert!((f.1 - s.1).abs() < 1e-12, "d={d} qi={qi}");
                }
            }
        }
    }

    #[test]
    fn knn_without_exclusion_includes_self() {
        let mut rng = Pcg32::seeded(32);
        let pts = Points::new(2, rng.uniform_vec(100 * 2, 0.0, 1.0));
        let tree = Tree::build(&pts, 8);
        let q = pts.point(5).to_vec();
        let res = knn(&tree, &q, 3, usize::MAX);
        assert_eq!(res[0].0, 5);
        assert!(res[0].1 < 1e-15);
    }

    #[test]
    fn knn_k_larger_than_n() {
        let mut rng = Pcg32::seeded(33);
        let pts = Points::new(2, rng.uniform_vec(5 * 2, 0.0, 1.0));
        let tree = Tree::build(&pts, 2);
        let res = knn(&tree, pts.point(0), 10, 0);
        assert_eq!(res.len(), 4);
    }

    #[test]
    fn knn_on_clustered_data() {
        // Points in two clusters; neighbours of a cluster point must come
        // from the same cluster.
        let mut rng = Pcg32::seeded(34);
        let mut coords = Vec::new();
        for i in 0..200 {
            let base = if i < 100 { 0.0 } else { 50.0 };
            coords.push(base + rng.normal() * 0.1);
            coords.push(base + rng.normal() * 0.1);
        }
        let pts = Points::new(2, coords);
        let tree = Tree::build(&pts, 10);
        let res = knn(&tree, pts.point(3), 20, 3);
        for (idx, _) in res {
            assert!(idx < 100, "neighbour from wrong cluster");
        }
    }
}
