//! Binary space partitioning per paper §3.1, the far/near interaction plan
//! per eq. (2), and a best-first k-nearest-neighbour search (the
//! NearestNeighbors.jl role, needed by t-SNE's perplexity calibration).
//!
//! The decomposition starts from a hypercube root and repeatedly splits the
//! longest axis, placing the hyperplane at the point median *clamped* to the
//! window that keeps every child's aspect ratio (max side / min side) at or
//! below two — the paper's constraints (a)–(c). Nodes with at most
//! `leaf_capacity` points become leaves.

pub mod knn;
pub mod plan;

pub use knn::knn;
pub use plan::{FarFieldPlan, NodeInteraction};

use crate::points::Points;
use crate::pool::Exec;
use std::sync::Mutex;

/// A node of the BSP tree.
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    /// Hyperrectangle lower corner.
    pub lo: Vec<f64>,
    /// Hyperrectangle upper corner.
    pub hi: Vec<f64>,
    /// Expansion center (hyperrectangle center).
    pub center: Vec<f64>,
    /// Max distance from `center` to a *contained point* (the `max_{r'∈node}`
    /// of paper eq. 2, taken over the points actually present).
    pub radius: f64,
    /// Start of this node's range in the permuted order.
    pub start: usize,
    /// One-past-end of the range.
    pub end: usize,
    /// Child node ids (left, right); None for leaves.
    pub children: Option<(usize, usize)>,
    /// Parent node id; None for the root.
    pub parent: Option<usize>,
    /// Depth (root = 0).
    pub depth: usize,
}

impl Node {
    /// Number of points contained.
    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the node holds no points.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// Whether the node is a leaf.
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.children.is_none()
    }

    /// Aspect ratio: longest side / shortest side.
    pub fn aspect_ratio(&self) -> f64 {
        let mut smin = f64::INFINITY;
        let mut smax = 0.0f64;
        for a in 0..self.lo.len() {
            let s = self.hi[a] - self.lo[a];
            smin = smin.min(s);
            smax = smax.max(s);
        }
        if smin <= 0.0 {
            f64::INFINITY
        } else {
            smax / smin
        }
    }
}

/// BSP tree over a point set.
///
/// Points are permuted so every node's points are contiguous; `perm[i]`
/// gives the original index of the point at tree position `i`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tree {
    /// Ambient dimension.
    pub d: usize,
    /// All nodes; `nodes[0]` is the root, children always after parents.
    pub nodes: Vec<Node>,
    /// Permutation from tree position to original index.
    pub perm: Vec<usize>,
    /// Permuted copy of the points (contiguous per node, cache friendly).
    pub points: Points,
    /// Leaf node ids in order.
    pub leaves: Vec<usize>,
    /// Maximum points per leaf used at build time.
    pub leaf_capacity: usize,
}

/// Aspect-ratio bound from paper §3.1 ("keep the aspect ratio below two").
const MAX_ASPECT: f64 = 2.0;

impl Tree {
    /// Build the §3.1 decomposition with the given leaf capacity.
    pub fn build(points: &Points, leaf_capacity: usize) -> Tree {
        assert!(leaf_capacity >= 1);
        assert!(!points.is_empty(), "cannot build tree over empty set");
        let n = points.len();
        let d = points.d;
        let mut perm: Vec<usize> = (0..n).collect();
        let mut pts = points.clone();
        // Root: bounding box inflated to a hypercube (plus epsilon so points
        // on the boundary stay strictly inside).
        let (mut lo, mut hi) = points.bounding_box();
        let side = (0..d)
            .map(|a| hi[a] - lo[a])
            .fold(0.0f64, f64::max)
            .max(1e-12);
        for a in 0..d {
            let mid = 0.5 * (lo[a] + hi[a]);
            lo[a] = mid - 0.55 * side;
            hi[a] = mid + 0.55 * side;
        }
        let mut tree = Tree {
            d,
            nodes: Vec::new(),
            perm: Vec::new(),
            points: Points::empty(d),
            leaves: Vec::new(),
            leaf_capacity,
        };
        let root = tree.push_node(lo, hi, 0, n, None, 0, &pts, &perm);
        let mut stack = vec![root];
        while let Some(id) = stack.pop() {
            if tree.nodes[id].len() <= leaf_capacity {
                tree.leaves.push(id);
                continue;
            }
            match tree.split_node(id, &mut pts, &mut perm) {
                Some((l, r)) => {
                    // Push right first so left is processed first (stable
                    // ordering: leaves end up in left-to-right order).
                    stack.push(r);
                    stack.push(l);
                }
                None => tree.leaves.push(id),
            }
        }
        tree.perm = perm;
        tree.points = pts;
        tree
    }

    /// [`Tree::build`] with the top splits forked across an execution
    /// pool: each split past the size cutoff recurses on its two halves
    /// as concurrent subtree tasks, and the results are spliced back in
    /// exactly the id order the sequential stack loop would have
    /// allocated. The output is equal to `build`'s — same nodes, same
    /// permutation, same leaves, bit-for-bit — because every geometric
    /// step runs the same arithmetic on the same values in the same
    /// order; only *which thread* runs a subtree changes. Sequential
    /// contexts (or small inputs) fall through to `build` untouched.
    pub fn build_exec(points: &Points, leaf_capacity: usize, exec: Exec<'_>) -> Tree {
        assert!(leaf_capacity >= 1);
        assert!(!points.is_empty(), "cannot build tree over empty set");
        let n = points.len();
        let cutoff = fork_cutoff(n, leaf_capacity, exec.parallelism());
        if exec.is_seq() || n <= cutoff {
            return Tree::build(points, leaf_capacity);
        }
        let d = points.d;
        // Root seeding identical to `build`: bounding box inflated to a
        // hypercube, center from the box, radius over all points.
        let (mut lo, mut hi) = points.bounding_box();
        let side = (0..d)
            .map(|a| hi[a] - lo[a])
            .fold(0.0f64, f64::max)
            .max(1e-12);
        for a in 0..d {
            let mid = 0.5 * (lo[a] + hi[a]);
            lo[a] = mid - 0.55 * side;
            hi[a] = mid + 0.55 * side;
        }
        let center: Vec<f64> = (0..d).map(|a| 0.5 * (lo[a] + hi[a])).collect();
        let mut radius2 = 0.0f64;
        for i in 0..n {
            let p = points.point(i);
            let mut acc = 0.0;
            for a in 0..d {
                let t = p[a] - center[a];
                acc += t * t;
            }
            radius2 = radius2.max(acc);
        }
        let seed = Node {
            lo,
            hi,
            center,
            radius: radius2.sqrt(),
            start: 0,
            end: n,
            children: None,
            parent: None,
            depth: 0,
        };
        let task = SubtreeTask { seed, pts: points.clone(), perm: (0..n).collect() };
        build_subtree(task, leaf_capacity, cutoff, exec)
    }

    fn push_node(
        &mut self,
        lo: Vec<f64>,
        hi: Vec<f64>,
        start: usize,
        end: usize,
        parent: Option<usize>,
        depth: usize,
        pts: &Points,
        _perm: &[usize],
    ) -> usize {
        let d = self.d;
        let center: Vec<f64> = (0..d).map(|a| 0.5 * (lo[a] + hi[a])).collect();
        let mut radius2 = 0.0f64;
        for i in start..end {
            let p = pts.point(i);
            let mut acc = 0.0;
            for a in 0..d {
                let t = p[a] - center[a];
                acc += t * t;
            }
            radius2 = radius2.max(acc);
        }
        let id = self.nodes.len();
        self.nodes.push(Node {
            lo,
            hi,
            center,
            radius: radius2.sqrt(),
            start,
            end,
            children: None,
            parent,
            depth,
        });
        id
    }

    /// Split a node per §3.1; returns child ids, or None if unsplittable
    /// (all points coincident).
    ///
    /// Before splitting, the box is shrink-wrapped to the points' bounding
    /// box and re-inflated just enough to keep its own aspect ratio ≤ 2.
    /// With that normalization the aspect-window below always straddles the
    /// point median's axis range, so both children are provably non-empty
    /// and satisfy the aspect bound — no fallback paths needed.
    fn split_node(
        &mut self,
        id: usize,
        pts: &mut Points,
        perm: &mut [usize],
    ) -> Option<(usize, usize)> {
        let d = self.d;
        let (start, end, depth) = {
            let n = &self.nodes[id];
            (n.start, n.end, n.depth)
        };
        // Shrink-wrap: bounding box of the node's points.
        let mut blo = pts.point(start).to_vec();
        let mut bhi = blo.clone();
        for i in start + 1..end {
            let p = pts.point(i);
            for a in 0..d {
                blo[a] = blo[a].min(p[a]);
                bhi[a] = bhi[a].max(p[a]);
            }
        }
        let smax = (0..d).map(|a| bhi[a] - blo[a]).fold(0.0f64, f64::max);
        if smax <= 0.0 {
            return None; // all points coincident: leaf
        }
        // Re-inflate thin axes so the wrapped box has aspect ≤ 2.
        for a in 0..d {
            let s = bhi[a] - blo[a];
            if s < smax / MAX_ASPECT {
                let mid = 0.5 * (blo[a] + bhi[a]);
                blo[a] = mid - 0.5 * smax / MAX_ASPECT;
                bhi[a] = mid + 0.5 * smax / MAX_ASPECT;
            }
        }
        // Update this node's box to the wrapped one (tighter expansion
        // centers and radii; children need not tile the parent box).
        {
            let node = &mut self.nodes[id];
            node.lo = blo.clone();
            node.hi = bhi.clone();
            node.center = (0..d).map(|a| 0.5 * (blo[a] + bhi[a])).collect();
            let mut r2 = 0.0f64;
            for i in start..end {
                let p = pts.point(i);
                let mut acc = 0.0;
                for a in 0..d {
                    let t = p[a] - node.center[a];
                    acc += t * t;
                }
                r2 = r2.max(acc);
            }
            node.radius = r2.sqrt();
        }
        // Longest axis of the wrapped box (its point spread equals the side).
        let (axis, side) = (0..d)
            .map(|a| (a, bhi[a] - blo[a]))
            .fold((0, -1.0), |best, cur| if cur.1 > best.1 { cur } else { best });
        let lo_a = blo[axis];
        // Aspect window for the hyperplane offset t from lo_a.
        let mut other_min = f64::INFINITY;
        let mut other_max = 0.0f64;
        for a in 0..d {
            if a == axis {
                continue;
            }
            let s = bhi[a] - blo[a];
            other_min = other_min.min(s);
            other_max = other_max.max(s);
        }
        let (w_lo, w_hi) = if d == 1 {
            (0.0, side)
        } else {
            (
                (other_max / MAX_ASPECT).max(side - MAX_ASPECT * other_min),
                (MAX_ASPECT * other_min).min(side - other_max / MAX_ASPECT),
            )
        };
        debug_assert!(w_lo <= w_hi + 1e-12, "infeasible aspect window");
        // Median of point coordinates along the axis, clamped to the
        // window. `select_nth_unstable_by` finds the same element a full
        // sort would place at position len/2 — identical split planes —
        // in O(n) instead of O(n log n) per split; min/max (for the
        // degenerate-tie fallback below) come from a single linear pass.
        let mut coords: Vec<f64> = (start..end).map(|i| pts.point(i)[axis]).collect();
        let (mut cmin, mut cmax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &coords {
            cmin = cmin.min(v);
            cmax = cmax.max(v);
        }
        let mid_pos = coords.len() / 2;
        let (_, &mut median, _) =
            coords.select_nth_unstable_by(mid_pos, |a, b| a.partial_cmp(b).unwrap());
        let eps = 1e-9 * side;
        let t = (median - lo_a).clamp((w_lo + eps).min(w_hi), w_hi.max(w_lo + eps));
        let plane = lo_a + t;
        // Partition [start,end) by coordinate < plane. Points at the wrapped
        // box's extremes guarantee both sides are non-empty (plane strictly
        // inside the point spread), except for pathological float ties —
        // handle those by a midpoint fallback.
        let mut mid = partition_points(pts, perm, start, end, axis, plane);
        if mid == start || mid == end {
            let plane2 = 0.5 * (cmin + cmax);
            mid = partition_points(pts, perm, start, end, axis, plane2);
            if mid == start || mid == end {
                return None;
            }
            let (l, r) = self.make_children(id, start, mid, end, depth, pts, perm);
            return Some((l, r));
        }
        let (l, r) = self.make_children(id, start, mid, end, depth, pts, perm);
        Some((l, r))
    }

    fn make_children(
        &mut self,
        id: usize,
        start: usize,
        mid: usize,
        end: usize,
        depth: usize,
        pts: &Points,
        perm: &[usize],
    ) -> (usize, usize) {
        // Children start from their own shrink-wrapped bounding boxes
        // (inflated for aspect at their own split time).
        let wrap = |s: usize, e: usize| -> (Vec<f64>, Vec<f64>) {
            let d = pts.d;
            let mut lo = pts.point(s).to_vec();
            let mut hi = lo.clone();
            for i in s + 1..e {
                let p = pts.point(i);
                for a in 0..d {
                    lo[a] = lo[a].min(p[a]);
                    hi[a] = hi[a].max(p[a]);
                }
            }
            // Inflate for aspect ≤ 2 immediately so `aspect_ratio()` holds
            // for leaves too.
            let smax = (0..d).map(|a| hi[a] - lo[a]).fold(0.0f64, f64::max).max(1e-300);
            for a in 0..d {
                let s2 = hi[a] - lo[a];
                if s2 < smax / MAX_ASPECT {
                    let m = 0.5 * (lo[a] + hi[a]);
                    lo[a] = m - 0.5 * smax / MAX_ASPECT;
                    hi[a] = m + 0.5 * smax / MAX_ASPECT;
                }
            }
            (lo, hi)
        };
        let (llo, lhi) = wrap(start, mid);
        let (rlo, rhi) = wrap(mid, end);
        let left = self.push_node(llo, lhi, start, mid, Some(id), depth + 1, pts, perm);
        let right = self.push_node(rlo, rhi, mid, end, Some(id), depth + 1, pts, perm);
        self.nodes[id].children = Some((left, right));
        (left, right)
    }

    /// Number of points in the tree.
    pub fn len(&self) -> usize {
        self.nodes[0].len()
    }

    /// True when the tree holds no points (never: build panics on empty).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum leaf depth.
    pub fn max_depth(&self) -> usize {
        self.leaves.iter().map(|&l| self.nodes[l].depth).max().unwrap_or(0)
    }

    /// Original indices of the points in `node`.
    pub fn node_indices(&self, node: usize) -> &[usize] {
        let n = &self.nodes[node];
        &self.perm[n.start..n.end]
    }

    /// Minimum squared distance from a query point to a node's box.
    #[inline]
    pub fn box_dist2(&self, node: usize, q: &[f64]) -> f64 {
        let nd = &self.nodes[node];
        let mut acc = 0.0;
        for a in 0..self.d {
            let v = q[a];
            let lo = nd.lo[a];
            let hi = nd.hi[a];
            let t = if v < lo {
                lo - v
            } else if v > hi {
                v - hi
            } else {
                0.0
            };
            acc += t * t;
        }
        acc
    }
}

/// Subtree sizes at or below this run as one sequential task; larger
/// ones split here and fork both halves. `n / (4·par)` keeps roughly
/// `4·par` leaf tasks in flight for balance; the floors stop the
/// recursion from forking work too small to pay for its range copy.
fn fork_cutoff(n: usize, leaf_capacity: usize, parallelism: usize) -> usize {
    (n / (4 * parallelism.max(1))).max(2 * leaf_capacity).max(512)
}

/// One forked build task: the seeded root geometry plus owned copies of
/// the range's points and range-local permutation (`[0, len)`). Owning
/// the range makes tasks freely `Send` without aliasing the parent's
/// buffers.
struct SubtreeTask {
    seed: Node,
    pts: Points,
    perm: Vec<usize>,
}

/// Carve a child task out of an already-partitioned parent range:
/// rebase the child node to `[0, len)` at depth 0 and copy its slice of
/// points and permutation.
fn make_subtask(
    child: &Node,
    pts: &Points,
    perm: &[usize],
    start: usize,
    end: usize,
) -> SubtreeTask {
    let d = pts.d;
    let seed = Node {
        lo: child.lo.clone(),
        hi: child.hi.clone(),
        center: child.center.clone(),
        radius: child.radius,
        start: 0,
        end: end - start,
        children: None,
        parent: None,
        depth: 0,
    };
    let coords = pts.coords[start * d..end * d].to_vec();
    SubtreeTask { seed, pts: Points::new(d, coords), perm: perm[start..end].to_vec() }
}

/// Build one task's subtree. Above the cutoff: split the seeded root
/// sequentially (the split itself is inherently serial — it partitions
/// the whole range) and recurse on both halves as pool tasks. At or
/// below it: replay the exact stack loop of [`Tree::build`] over the
/// owned range. Seeding the local root with the parent-made geometry —
/// rather than a fresh hypercube — is what keeps the unsplittable edge
/// cases (coincident points, degenerate ties) bit-identical to the
/// sequential build, which leaves such nodes with their creation box.
fn build_subtree(task: SubtreeTask, leaf_capacity: usize, cutoff: usize, exec: Exec<'_>) -> Tree {
    let SubtreeTask { seed, mut pts, mut perm } = task;
    let d = pts.d;
    let len = seed.end;
    if exec.is_seq() || len <= cutoff {
        return build_range_sequential(seed, pts, perm, leaf_capacity);
    }
    let mut tree = Tree {
        d,
        nodes: vec![seed],
        perm: Vec::new(),
        points: Points::empty(d),
        leaves: Vec::new(),
        leaf_capacity,
    };
    if tree.split_node(0, &mut pts, &mut perm).is_none() {
        // Unsplittable despite its size: a single (over-full) leaf,
        // exactly as the sequential loop would record it.
        tree.leaves.push(0);
        tree.perm = perm;
        tree.points = pts;
        return tree;
    }
    let mid = tree.nodes[1].end;
    let cells = [
        Mutex::new(Some(make_subtask(&tree.nodes[1], &pts, &perm, 0, mid))),
        Mutex::new(Some(make_subtask(&tree.nodes[2], &pts, &perm, mid, len))),
    ];
    let mut halves = exec.map(2, &|i| {
        let sub = cells[i]
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("each subtree task is taken exactly once");
        build_subtree(sub, leaf_capacity, cutoff, exec)
    });
    let right = halves.pop().expect("right half");
    let left = halves.pop().expect("left half");
    let mut root = tree.nodes.swap_remove(0);
    root.children = Some((1, 2));
    splice_halves(root, perm, left, right, leaf_capacity)
}

/// The sequential base case: the exact stack loop of [`Tree::build`],
/// run over an owned range with a pre-seeded root node.
fn build_range_sequential(
    seed: Node,
    mut pts: Points,
    mut perm: Vec<usize>,
    leaf_capacity: usize,
) -> Tree {
    let d = pts.d;
    let mut tree = Tree {
        d,
        nodes: vec![seed],
        perm: Vec::new(),
        points: Points::empty(d),
        leaves: Vec::new(),
        leaf_capacity,
    };
    let mut stack = vec![0usize];
    while let Some(id) = stack.pop() {
        if tree.nodes[id].len() <= leaf_capacity {
            tree.leaves.push(id);
            continue;
        }
        match tree.split_node(id, &mut pts, &mut perm) {
            Some((l, r)) => {
                stack.push(r);
                stack.push(l);
            }
            None => tree.leaves.push(id),
        }
    }
    tree.perm = perm;
    tree.points = pts;
    tree
}

/// Merge two recursively built halves under their split root,
/// renumbering into the sequential id layout. The stack discipline of
/// [`Tree::build`] allocates ids as `[v, L, R, descendants of L...,
/// descendants of R...]` for every split node `v` (children are
/// allocated pairwise at split time, and the left subtree is fully
/// processed before the right sibling is popped), and each half's arena
/// is — by induction — already in that layout locally. So the final
/// numbering is a pure index shift: left id `j` maps to `1` (root) or
/// `j + 2`; right id `j` maps to `2` or `|L| + 1 + j`.
fn splice_halves(
    root: Node,
    perm: Vec<usize>,
    left: Tree,
    right: Tree,
    leaf_capacity: usize,
) -> Tree {
    let d = left.d;
    let mid = left.perm.len();
    let n = root.end;
    let size_l = left.nodes.len();
    let map_l = |j: usize| if j == 0 { 1 } else { j + 2 };
    let map_r = |j: usize| if j == 0 { 2 } else { size_l + 1 + j };
    let remap = |node: &Node, off: usize, map: &dyn Fn(usize) -> usize| -> Node {
        let mut out = node.clone();
        out.start += off;
        out.end += off;
        out.depth += 1;
        out.parent = Some(node.parent.map_or(0, map));
        out.children = node.children.map(|(a, b)| (map(a), map(b)));
        out
    };
    let mut nodes: Vec<Node> = Vec::with_capacity(1 + size_l + right.nodes.len());
    nodes.push(root);
    nodes.push(remap(&left.nodes[0], 0, &map_l));
    nodes.push(remap(&right.nodes[0], mid, &map_r));
    for node in &left.nodes[1..] {
        nodes.push(remap(node, 0, &map_l));
    }
    for node in &right.nodes[1..] {
        nodes.push(remap(node, mid, &map_r));
    }
    let mut leaves: Vec<usize> = left.leaves.iter().map(|&j| map_l(j)).collect();
    leaves.extend(right.leaves.iter().map(|&j| map_r(j)));
    let mut out_perm: Vec<usize> = Vec::with_capacity(n);
    out_perm.extend(left.perm.iter().map(|&j| perm[j]));
    out_perm.extend(right.perm.iter().map(|&j| perm[mid + j]));
    let mut coords = left.points.coords;
    coords.extend_from_slice(&right.points.coords);
    Tree { d, nodes, perm: out_perm, points: Points::new(d, coords), leaves, leaf_capacity }
}

/// Partition tree positions [start,end) so points with coord < plane come
/// first; returns the split position. Keeps `pts` and `perm` in sync.
fn partition_points(
    pts: &mut Points,
    perm: &mut [usize],
    start: usize,
    end: usize,
    axis: usize,
    plane: f64,
) -> usize {
    let d = pts.d;
    let mut i = start;
    let mut j = end;
    while i < j {
        if pts.coords[i * d + axis] < plane {
            i += 1;
        } else {
            j -= 1;
            // swap points i and j
            for a in 0..d {
                pts.coords.swap(i * d + a, j * d + a);
            }
            perm.swap(i, j);
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn uniform_points(n: usize, d: usize, seed: u64) -> Points {
        let mut rng = Pcg32::seeded(seed);
        Points::new(d, rng.uniform_vec(n * d, 0.0, 1.0))
    }

    #[test]
    fn all_points_in_exactly_one_leaf() {
        let pts = uniform_points(500, 3, 1);
        let tree = Tree::build(&pts, 32);
        let mut seen = vec![0usize; 500];
        for &l in &tree.leaves {
            for &orig in tree.node_indices(l) {
                seen[orig] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn leaves_respect_capacity() {
        let pts = uniform_points(1000, 2, 2);
        let tree = Tree::build(&pts, 50);
        for &l in &tree.leaves {
            assert!(tree.nodes[l].len() <= 50, "leaf overflow");
            assert!(!tree.nodes[l].is_empty(), "empty leaf");
        }
    }

    #[test]
    fn children_partition_parents() {
        let pts = uniform_points(400, 3, 3);
        let tree = Tree::build(&pts, 16);
        for (id, node) in tree.nodes.iter().enumerate() {
            if let Some((l, r)) = node.children {
                assert_eq!(tree.nodes[l].start, node.start);
                assert_eq!(tree.nodes[l].end, tree.nodes[r].start);
                assert_eq!(tree.nodes[r].end, node.end);
                assert_eq!(tree.nodes[l].parent, Some(id));
                assert_eq!(tree.nodes[r].parent, Some(id));
            }
        }
    }

    #[test]
    fn points_inside_their_boxes() {
        let pts = uniform_points(300, 4, 4);
        let tree = Tree::build(&pts, 20);
        for node in &tree.nodes {
            for i in node.start..node.end {
                let p = tree.points.point(i);
                for a in 0..tree.d {
                    assert!(
                        p[a] >= node.lo[a] - 1e-12 && p[a] <= node.hi[a] + 1e-12,
                        "point escapes box"
                    );
                }
            }
        }
    }

    #[test]
    fn aspect_ratio_bounded_by_two() {
        for d in [2usize, 3, 5] {
            let pts = uniform_points(800, d, 5 + d as u64);
            let tree = Tree::build(&pts, 10);
            for node in &tree.nodes {
                assert!(
                    node.aspect_ratio() <= MAX_ASPECT + 1e-9,
                    "aspect {} in d={d}",
                    node.aspect_ratio()
                );
            }
        }
    }

    #[test]
    fn radius_covers_contained_points() {
        let pts = uniform_points(300, 3, 6);
        let tree = Tree::build(&pts, 25);
        for node in &tree.nodes {
            for i in node.start..node.end {
                let p = tree.points.point(i);
                let dist = crate::linalg::vecops::dist2(p, &node.center).sqrt();
                assert!(dist <= node.radius + 1e-12);
            }
        }
    }

    #[test]
    fn duplicated_points_become_a_leaf_not_infinite_loop() {
        let mut coords = Vec::new();
        for _ in 0..100 {
            coords.extend_from_slice(&[0.25, 0.75]);
        }
        let pts = Points::new(2, coords);
        let tree = Tree::build(&pts, 10);
        // Can't split identical points: one (over-full) leaf is acceptable.
        assert_eq!(tree.len(), 100);
        let total: usize = tree.leaves.iter().map(|&l| tree.nodes[l].len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn splits_are_roughly_balanced_on_uniform_data() {
        let pts = uniform_points(4096, 2, 7);
        let tree = Tree::build(&pts, 64);
        // Expected depth ~ log2(4096/64) = 6; allow slack for clamping.
        assert!(tree.max_depth() <= 10, "depth {}", tree.max_depth());
    }

    #[test]
    fn clustered_data_adapts() {
        // Two tight clusters far apart: tree must terminate and give leaves
        // within capacity.
        let mut rng = Pcg32::seeded(8);
        let mut coords = Vec::new();
        for i in 0..600 {
            let base = if i % 2 == 0 { 0.0 } else { 100.0 };
            coords.push(base + rng.normal() * 0.01);
            coords.push(base + rng.normal() * 0.01);
        }
        let pts = Points::new(2, coords);
        let tree = Tree::build(&pts, 30);
        for &l in &tree.leaves {
            assert!(tree.nodes[l].len() <= 30);
        }
    }

    /// Field-wise tree comparison with readable failures (a whole-tree
    /// `assert_eq!` would dump thousands of nodes).
    fn assert_trees_equal(seq: &Tree, par: &Tree, label: &str) {
        assert_eq!(seq.perm, par.perm, "{label}: permutation differs");
        assert_eq!(seq.leaves, par.leaves, "{label}: leaf order differs");
        assert_eq!(seq.points, par.points, "{label}: permuted coordinates differ");
        assert_eq!(seq.nodes.len(), par.nodes.len(), "{label}: node count differs");
        for (id, (a, b)) in seq.nodes.iter().zip(&par.nodes).enumerate() {
            assert_eq!(a, b, "{label}: node {id} differs");
        }
    }

    #[test]
    fn parallel_build_equals_sequential_bitwise() {
        let pool = crate::pool::WorkerPool::new(4);
        for (n, d, leaf, seed) in
            [(3000usize, 3usize, 32usize, 11u64), (5000, 2, 64, 12), (2000, 5, 16, 13)]
        {
            let pts = uniform_points(n, d, seed);
            let seq = Tree::build(&pts, leaf);
            for slots in [2usize, 4] {
                let par = Tree::build_exec(&pts, leaf, Exec::Pool { pool: &pool, slots });
                assert_trees_equal(&seq, &par, &format!("n={n} d={d} leaf={leaf} slots={slots}"));
            }
            // The sequential context must be the sequential build verbatim.
            let via_seq = Tree::build_exec(&pts, leaf, Exec::Seq);
            assert_trees_equal(&seq, &via_seq, "Exec::Seq");
        }
    }

    #[test]
    fn parallel_build_handles_coincident_and_clustered_ranges() {
        let pool = crate::pool::WorkerPool::new(4);
        // A coincident block big enough to be forked as its own subtree
        // task, glued to a uniform cloud: exercises the unsplittable
        // (None-returning) paths inside forked tasks.
        let mut rng = Pcg32::seeded(21);
        let mut coords = Vec::new();
        for _ in 0..1500 {
            coords.extend_from_slice(&[0.125, 0.875]);
        }
        coords.extend(rng.uniform_vec(1500 * 2, 10.0, 11.0));
        let pts = Points::new(2, coords);
        let seq = Tree::build(&pts, 20);
        let par = Tree::build_exec(&pts, 20, Exec::Pool { pool: &pool, slots: 4 });
        assert_trees_equal(&seq, &par, "coincident block");

        // Two tight distant clusters (heavily clamped split planes).
        let mut coords = Vec::new();
        for i in 0..4000 {
            let base = if i % 2 == 0 { 0.0 } else { 100.0 };
            coords.push(base + rng.normal() * 0.01);
            coords.push(base + rng.normal() * 0.01);
        }
        let pts = Points::new(2, coords);
        let seq = Tree::build(&pts, 30);
        let par = Tree::build_exec(&pts, 30, Exec::Pool { pool: &pool, slots: 3 });
        assert_trees_equal(&seq, &par, "clustered");
    }

    #[test]
    fn box_dist2_is_zero_inside_positive_outside() {
        let pts = uniform_points(50, 2, 9);
        let tree = Tree::build(&pts, 10);
        let root = &tree.nodes[0];
        let inside: Vec<f64> = root.center.clone();
        assert_eq!(tree.box_dist2(0, &inside), 0.0);
        let outside: Vec<f64> = root.hi.iter().map(|&h| h + 1.0).collect();
        assert!(tree.box_dist2(0, &outside) > 0.0);
    }
}
