//! Far/near interaction planning — paper §3.1 eq. (2) and §3.2.
//!
//! Given a source tree and a set of target points, compute for every node
//! `b` the set `F_b` of targets far enough for compression, and for every
//! leaf `l` the residual near set `N_l`, such that every (target, source)
//! pair is covered **exactly once**: by the unique shallowest ancestor of
//! the source's leaf whose far set contains the target, or by the leaf's
//! near set. This exact-cover property is what makes Algorithm 1 an
//! (approximate) evaluation of the full kernel sum, and it is property-
//! tested in `rust/tests/`.

use super::{Node, Tree};
use crate::linalg::vecops;
use crate::points::Points;
use crate::pool::Exec;
use std::collections::VecDeque;
use std::rc::Rc;

/// Interaction lists for one node.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeInteraction {
    /// Target indices judged far by eq. (2) at this node.
    pub far: Vec<u32>,
    /// Target indices remaining at this leaf (empty for internal nodes).
    pub near: Vec<u32>,
}

/// The complete far/near plan for a (source tree, target set, θ) triple.
#[derive(Clone, Debug)]
pub struct FarFieldPlan {
    /// Per-node interaction lists, indexed like `tree.nodes`.
    pub interactions: Vec<NodeInteraction>,
    /// Distance criterion parameter θ ∈ (0, 1) of eq. (2).
    pub theta: f64,
    /// Total number of (node, far-target) pairs.
    pub far_pairs: usize,
    /// Total number of (leaf, near-target) pairs.
    pub near_pairs: usize,
}

impl FarFieldPlan {
    /// Build the plan. `targets` may be the tree's own (original-order)
    /// points for a square MVM, or any other point set (GP prediction).
    ///
    /// A target t is *far* from node b when `radius_b / |t - c_b| < θ`
    /// (paper eq. 2 rearranged), i.e. the node subtends a small enough
    /// angle. θ < 1 guarantees the separation `r' < r` required for the
    /// expansion of Theorem 3.1 to converge.
    pub fn build(tree: &Tree, targets: &Points, theta: f64) -> FarFieldPlan {
        assert!(theta > 0.0 && theta < 1.0, "theta must be in (0,1)");
        assert_eq!(targets.d, tree.d, "dimension mismatch");
        let nnodes = tree.nodes.len();
        let mut interactions: Vec<NodeInteraction> = vec![NodeInteraction::default(); nnodes];
        let mut far_pairs = 0usize;
        let mut near_pairs = 0usize;
        // Depth-first with an explicit stack. Both children consume the
        // same surviving candidate list, which is *shared* through an Rc
        // instead of deep-cloned per internal node (the previous
        // construction's `rest.clone()` was an O(N log N) redundant
        // allocation per plan build). An explicit stack rather than
        // recursion because the aspect-window-clamped splits do not bound
        // the tree depth by log N on adversarial point sets.
        let all: Rc<Vec<u32>> = Rc::new((0..targets.len() as u32).collect());
        let mut stack: Vec<(usize, Rc<Vec<u32>>)> = vec![(0, all)];
        while let Some((id, cand)) = stack.pop() {
            let node = &tree.nodes[id];
            let (far, rest) = partition_candidates(node, targets, &cand, theta);
            far_pairs += far.len();
            match node.children {
                Some((l, r)) => {
                    interactions[id].far = far;
                    let rest = Rc::new(rest);
                    stack.push((r, Rc::clone(&rest)));
                    stack.push((l, rest));
                }
                None => {
                    near_pairs += rest.len();
                    interactions[id].far = far;
                    interactions[id].near = rest;
                }
            }
        }
        FarFieldPlan { interactions, theta, far_pairs, near_pairs }
    }

    /// [`FarFieldPlan::build`] with independent subtrees processed
    /// concurrently on an execution pool. A node's interaction lists
    /// depend only on the node and the candidate list it inherits —
    /// both of which are identical to the sequential build's (candidate
    /// order is preserved parent → child) — so the result is equal to
    /// `build`'s bit for bit regardless of which thread descends which
    /// subtree. Sequential contexts and small plans fall through to
    /// `build` untouched.
    pub fn build_exec(tree: &Tree, targets: &Points, theta: f64, exec: Exec<'_>) -> FarFieldPlan {
        assert!(theta > 0.0 && theta < 1.0, "theta must be in (0,1)");
        assert_eq!(targets.d, tree.d, "dimension mismatch");
        let par = exec.parallelism();
        if par <= 1 || tree.nodes.len() < 32 || targets.len() < 1024 {
            return FarFieldPlan::build(tree, targets, theta);
        }
        let nnodes = tree.nodes.len();
        let mut interactions: Vec<NodeInteraction> = vec![NodeInteraction::default(); nnodes];
        let mut far_pairs = 0usize;
        let mut near_pairs = 0usize;
        // Phase 1: breadth-first expansion near the root (recording those
        // nodes' lists as it goes) until enough independent subtree tasks
        // exist to keep the pool busy. Candidates are owned per entry —
        // the clones are confined to these first ~4·par shallow nodes.
        let target_tasks = 4 * par;
        let mut queue: VecDeque<(usize, Vec<u32>)> = VecDeque::new();
        queue.push_back((0, (0..targets.len() as u32).collect()));
        while queue.len() < target_tasks {
            let Some((id, cand)) = queue.pop_front() else { break };
            let node = &tree.nodes[id];
            let (far, rest) = partition_candidates(node, targets, &cand, theta);
            far_pairs += far.len();
            match node.children {
                Some((l, r)) => {
                    interactions[id].far = far;
                    queue.push_back((l, rest.clone()));
                    queue.push_back((r, rest));
                }
                None => {
                    near_pairs += rest.len();
                    interactions[id].far = far;
                    interactions[id].near = rest;
                }
            }
        }
        // Phase 2: one pool task per frontier subtree, each running the
        // sequential depth-first descent locally.
        let tasks: Vec<(usize, Vec<u32>)> = queue.into();
        let results = exec.map(tasks.len(), &|i| {
            let (root, cand) = &tasks[i];
            descend_subtree(tree, targets, theta, *root, cand)
        });
        // Phase 3: merge — disjoint node sets, so plain overwrites.
        for (list, fp, np) in results {
            far_pairs += fp;
            near_pairs += np;
            for (id, it) in list {
                interactions[id] = it;
            }
        }
        FarFieldPlan { interactions, theta, far_pairs, near_pairs }
    }

    /// Ids of nodes with a non-empty far set, in ascending order — the
    /// nodes whose moments are actually consumed. This is the candidate
    /// list the panel cache's budget planner and the apply scheduler's
    /// job construction both iterate (`fkt::panels`).
    pub fn nodes_with_far(&self) -> impl Iterator<Item = usize> + '_ {
        self.interactions
            .iter()
            .enumerate()
            .filter(|(_, it)| !it.far.is_empty())
            .map(|(id, _)| id)
    }

    /// Estimated dense-equivalent work: near pairs × leaf sizes etc.
    /// (used by the coordinator's cost model and by the benches' reporting).
    pub fn stats(&self, tree: &Tree) -> PlanStats {
        let mut near_flops = 0usize;
        let mut far_targets_max = 0usize;
        for (id, it) in self.interactions.iter().enumerate() {
            let node = &tree.nodes[id];
            if node.is_leaf() {
                near_flops += it.near.len() * node.len();
            }
            far_targets_max = far_targets_max.max(it.far.len());
        }
        PlanStats {
            far_pairs: self.far_pairs,
            near_pairs: self.near_pairs,
            near_flops,
            far_targets_max,
        }
    }
}

/// Split a candidate list into (far, rest) for one node by the eq. (2)
/// criterion, preserving candidate order. A node containing a single
/// point has radius 0 and everything (except coincident points) is far.
fn partition_candidates(
    node: &Node,
    targets: &Points,
    cand: &[u32],
    theta: f64,
) -> (Vec<u32>, Vec<u32>) {
    let mut far = Vec::new();
    let mut rest = Vec::new();
    let rad = node.radius;
    for &t in cand {
        let tp = targets.point(t as usize);
        let dist = vecops::dist2(tp, &node.center).sqrt();
        if dist > 0.0 && rad / dist < theta {
            far.push(t);
        } else {
            rest.push(t);
        }
    }
    (far, rest)
}

/// Sequential depth-first descent of the subtree rooted at `root` with
/// inherited candidate list `cand` — the body of [`FarFieldPlan::build`]
/// replayed locally. Returns the visited nodes' interactions plus the
/// subtree's pair counts. The `Rc` candidate sharing never leaves this
/// function, so the routine is safe to run from any pool worker.
fn descend_subtree(
    tree: &Tree,
    targets: &Points,
    theta: f64,
    root: usize,
    cand: &[u32],
) -> (Vec<(usize, NodeInteraction)>, usize, usize) {
    let mut out: Vec<(usize, NodeInteraction)> = Vec::new();
    let mut far_pairs = 0usize;
    let mut near_pairs = 0usize;
    let mut stack: Vec<(usize, Rc<Vec<u32>>)> = vec![(root, Rc::new(cand.to_vec()))];
    while let Some((id, cand)) = stack.pop() {
        let node = &tree.nodes[id];
        let (far, rest) = partition_candidates(node, targets, &cand, theta);
        far_pairs += far.len();
        match node.children {
            Some((l, r)) => {
                out.push((id, NodeInteraction { far, near: Vec::new() }));
                let rest = Rc::new(rest);
                stack.push((r, Rc::clone(&rest)));
                stack.push((l, rest));
            }
            None => {
                near_pairs += rest.len();
                out.push((id, NodeInteraction { far, near: rest }));
            }
        }
    }
    (out, far_pairs, near_pairs)
}

/// Summary statistics of a plan.
#[derive(Clone, Copy, Debug)]
pub struct PlanStats {
    /// Total (node, far target) pairs.
    pub far_pairs: usize,
    /// Total (leaf, near target) pairs.
    pub near_pairs: usize,
    /// Σ_leaf |N_l|·|l| — multiply-adds in the dense near field.
    pub near_flops: usize,
    /// Largest single far set (batching granularity).
    pub far_targets_max: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn uniform_points(n: usize, d: usize, seed: u64) -> Points {
        let mut rng = Pcg32::seeded(seed);
        Points::new(d, rng.uniform_vec(n * d, 0.0, 1.0))
    }

    /// The exact-cover invariant: summing indicator contributions over the
    /// plan reproduces the all-ones N×N matrix.
    fn check_exact_cover(n: usize, d: usize, theta: f64, leaf: usize, seed: u64) {
        let pts = uniform_points(n, d, seed);
        let tree = Tree::build(&pts, leaf);
        let plan = FarFieldPlan::build(&tree, &pts, theta);
        // count[t][s] via flattened vec
        let mut count = vec![0u8; n * n];
        for (id, it) in plan.interactions.iter().enumerate() {
            let srcs = tree.node_indices(id);
            for &t in &it.far {
                for &s in srcs {
                    count[t as usize * n + s] += 1;
                }
            }
            if tree.nodes[id].is_leaf() {
                for &t in &it.near {
                    for &s in srcs {
                        count[t as usize * n + s] += 1;
                    }
                }
            }
        }
        for t in 0..n {
            for s in 0..n {
                assert_eq!(count[t * n + s], 1, "pair ({t},{s}) covered {} times", count[t * n + s]);
            }
        }
    }

    #[test]
    fn exact_cover_2d() {
        check_exact_cover(300, 2, 0.5, 16, 1);
    }

    #[test]
    fn exact_cover_3d_aggressive_theta() {
        check_exact_cover(200, 3, 0.75, 8, 2);
    }

    #[test]
    fn exact_cover_conservative_theta() {
        check_exact_cover(150, 2, 0.25, 32, 3);
    }

    #[test]
    fn exact_cover_high_dim() {
        check_exact_cover(120, 5, 0.6, 10, 4);
    }

    #[test]
    fn far_sets_respect_separation() {
        let pts = uniform_points(400, 3, 5);
        let tree = Tree::build(&pts, 20);
        let theta = 0.6;
        let plan = FarFieldPlan::build(&tree, &pts, theta);
        for (id, it) in plan.interactions.iter().enumerate() {
            let node = &tree.nodes[id];
            for &t in &it.far {
                let dist = vecops::dist2(pts.point(t as usize), &node.center).sqrt();
                assert!(node.radius / dist < theta);
            }
        }
    }

    #[test]
    fn cross_targets_cover_all_pairs() {
        // Distinct target set (GP prediction scenario).
        let src = uniform_points(150, 2, 6);
        let tgt = uniform_points(80, 2, 7);
        let tree = Tree::build(&src, 16);
        let plan = FarFieldPlan::build(&tree, &tgt, 0.5);
        let n = src.len();
        let m = tgt.len();
        let mut count = vec![0u8; m * n];
        for (id, it) in plan.interactions.iter().enumerate() {
            let srcs = tree.node_indices(id);
            for &t in &it.far {
                for &s in srcs {
                    count[t as usize * n + s] += 1;
                }
            }
            if tree.nodes[id].is_leaf() {
                for &t in &it.near {
                    for &s in srcs {
                        count[t as usize * n + s] += 1;
                    }
                }
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn smaller_theta_shifts_mass_from_far_to_near() {
        let pts = uniform_points(500, 2, 8);
        let tree = Tree::build(&pts, 32);
        let loose = FarFieldPlan::build(&tree, &pts, 0.75);
        let tight = FarFieldPlan::build(&tree, &pts, 0.25);
        // A tighter θ compresses less: more dense near-field work.
        assert!(tight.near_pairs > loose.near_pairs);
        // Interaction mass (pairs of points covered far vs near) conserves:
        // Σ_far |b| + Σ_near |l| = N².
        let mass = |plan: &FarFieldPlan| -> (usize, usize) {
            let mut farm = 0;
            let mut nearm = 0;
            for (id, it) in plan.interactions.iter().enumerate() {
                farm += it.far.len() * tree.nodes[id].len();
                nearm += it.near.len() * tree.nodes[id].len();
            }
            (farm, nearm)
        };
        let (lf, ln) = mass(&loose);
        let (tf, tn) = mass(&tight);
        assert_eq!(lf + ln, 500 * 500);
        assert_eq!(tf + tn, 500 * 500);
        assert!(tf < lf, "tight θ must compress less mass");
    }

    /// The pre-refactor construction (explicit stack, `rest.clone()` per
    /// internal node) — kept verbatim as the reference the allocation-free
    /// rewrite must reproduce exactly.
    fn build_reference(tree: &Tree, targets: &Points, theta: f64) -> FarFieldPlan {
        let nnodes = tree.nodes.len();
        let mut interactions: Vec<NodeInteraction> = vec![NodeInteraction::default(); nnodes];
        let mut far_pairs = 0usize;
        let mut near_pairs = 0usize;
        let all: Vec<u32> = (0..targets.len() as u32).collect();
        let mut stack: Vec<(usize, Vec<u32>)> = vec![(0, all)];
        while let Some((id, cand)) = stack.pop() {
            let node = &tree.nodes[id];
            let mut far = Vec::new();
            let mut rest = Vec::new();
            let rad = node.radius;
            for &t in &cand {
                let tp = targets.point(t as usize);
                let dist = vecops::dist2(tp, &node.center).sqrt();
                if dist > 0.0 && rad / dist < theta {
                    far.push(t);
                } else {
                    rest.push(t);
                }
            }
            far_pairs += far.len();
            match node.children {
                Some((l, r)) => {
                    interactions[id].far = far;
                    stack.push((r, rest.clone()));
                    stack.push((l, rest));
                }
                None => {
                    near_pairs += rest.len();
                    interactions[id].far = far;
                    interactions[id].near = rest;
                }
            }
        }
        FarFieldPlan { interactions, theta, far_pairs, near_pairs }
    }

    #[test]
    fn clone_free_build_equals_reference_construction() {
        // Square and rectangular target sets, several θ/leaf shapes: the
        // rewritten build must produce bit-identical interaction lists
        // (same targets, same order) and identical pair counts.
        for (n, m, d, theta, leaf, seed) in [
            (300, 300, 2, 0.5, 16, 21),
            (200, 90, 3, 0.75, 8, 22),
            (150, 150, 2, 0.25, 32, 23),
            (1, 5, 2, 0.5, 4, 24), // single-source degenerate tree
        ] {
            let src = uniform_points(n, d, seed);
            let tgt = if n == m { src.clone() } else { uniform_points(m, d, seed + 100) };
            let tree = Tree::build(&src, leaf);
            let new = FarFieldPlan::build(&tree, &tgt, theta);
            let old = build_reference(&tree, &tgt, theta);
            assert_eq!(new.far_pairs, old.far_pairs);
            assert_eq!(new.near_pairs, old.near_pairs);
            assert_eq!(new.interactions.len(), old.interactions.len());
            for (id, (a, b)) in new.interactions.iter().zip(&old.interactions).enumerate() {
                assert_eq!(a, b, "node {id} interaction lists differ");
            }
        }
    }

    #[test]
    fn parallel_plan_build_equals_sequential_bitwise() {
        use crate::pool::WorkerPool;
        let pool = WorkerPool::new(4);
        for (n, d, theta, leaf, seed) in
            [(3000, 3, 0.5, 32, 31), (2000, 2, 0.75, 16, 32), (1500, 4, 0.3, 24, 33)]
        {
            let pts = uniform_points(n, d, seed);
            let tree = Tree::build(&pts, leaf);
            let seq = FarFieldPlan::build(&tree, &pts, theta);
            for slots in [2usize, 4] {
                let exec = Exec::Pool { pool: &pool, slots };
                let par = FarFieldPlan::build_exec(&tree, &pts, theta, exec);
                assert_eq!(par.far_pairs, seq.far_pairs);
                assert_eq!(par.near_pairs, seq.near_pairs);
                for (id, (a, b)) in par.interactions.iter().zip(&seq.interactions).enumerate() {
                    assert_eq!(a, b, "node {id} differs at slots={slots}");
                }
            }
            // Sequential exec must fall through to the reference path.
            let via_seq = FarFieldPlan::build_exec(&tree, &pts, theta, Exec::Seq);
            assert_eq!(via_seq.far_pairs, seq.far_pairs);
            assert_eq!(via_seq.interactions, seq.interactions);
        }
    }

    #[test]
    fn stats_consistent() {
        let pts = uniform_points(300, 2, 9);
        let tree = Tree::build(&pts, 16);
        let plan = FarFieldPlan::build(&tree, &pts, 0.5);
        let st = plan.stats(&tree);
        assert_eq!(st.far_pairs, plan.far_pairs);
        assert_eq!(st.near_pairs, plan.near_pairs);
        assert!(st.near_flops >= st.near_pairs);
    }
}
