//! t-distributed Stochastic Neighbor Embedding accelerated by the FKT
//! (paper §5.2, Fig 3).
//!
//! The gradient of the t-SNE objective splits into a sparse attractive
//! term over the kNN graph and a dense repulsive term
//! `F_rep,i = (1/Z) Σ_j w_ij² (y_i − y_j)`, `w_ij = (1+|y_i−y_j|²)^{-1}`,
//! `Z = Σ_{k≠l} w_kl` — sums of Cauchy and squared-Cauchy kernel MVMs over
//! the 2-D embedding, "a prime candidate for the application of FKT"
//! (paper). Per iteration the embedding moves, so the operator (tree +
//! plan) is rebuilt — the quasilinear build is part of the method's cost,
//! exactly as in the paper's comparison with van der Maaten's Barnes–Hut
//! t-SNE. Operators are requested through the [`Session`] as *transient*
//! builds: the moving embedding means an operator can never be requested
//! twice, so caching them would only fill the registry with dead entries
//! and evict genuinely reusable ones — each step's operators are built,
//! used, and dropped, exactly as the per-iteration cost model assumes.

use crate::fkt::FktConfig;
use crate::kernels::Family;
use crate::points::Points;
use crate::rng::Pcg32;
use crate::session::Session;
use crate::tree::{knn, Tree};

/// Sparse symmetric affinity matrix P in COO-per-row form.
#[derive(Clone, Debug)]
pub struct Affinities {
    /// Neighbor indices per row.
    pub cols: Vec<Vec<u32>>,
    /// p_ij values per row (same layout as cols).
    pub vals: Vec<Vec<f64>>,
}

/// t-SNE configuration.
#[derive(Clone, Copy, Debug)]
pub struct TsneConfig {
    /// Perplexity (paper/standard default 30).
    pub perplexity: f64,
    /// Total gradient iterations.
    pub iterations: usize,
    /// Early-exaggeration factor and duration.
    pub exaggeration: f64,
    /// Iterations with exaggeration active.
    pub exaggeration_iters: usize,
    /// Learning rate.
    pub learning_rate: f64,
    /// Momentum before/after the exaggeration phase.
    pub momentum_early: f64,
    /// Momentum after.
    pub momentum_late: f64,
    /// FKT settings for the repulsive field (2-D, Cauchy kernels).
    pub fkt: FktConfig,
    /// Compute repulsion exactly (O(N²)) — testing/small N only.
    pub exact_repulsion: bool,
    /// RNG seed for the embedding init.
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig {
            perplexity: 30.0,
            iterations: 500,
            exaggeration: 12.0,
            exaggeration_iters: 200,
            learning_rate: 200.0,
            momentum_early: 0.5,
            momentum_late: 0.8,
            fkt: FktConfig { p: 3, theta: 0.6, leaf_capacity: 128, ..Default::default() },
            exact_repulsion: false,
            seed: 7,
        }
    }
}

/// Compute the symmetrized perplexity-calibrated affinities on the kNN
/// graph (k = 3·perplexity, van der Maaten's convention).
pub fn compute_affinities(data: &Points, perplexity: f64) -> Affinities {
    let n = data.len();
    let k = ((3.0 * perplexity) as usize).min(n - 1).max(1);
    let tree = Tree::build(data, 32.max(k / 2));
    // Conditional distributions p_{j|i} on the kNN sets.
    let mut cond_cols: Vec<Vec<u32>> = Vec::with_capacity(n);
    let mut cond_vals: Vec<Vec<f64>> = Vec::with_capacity(n);
    let target_entropy = perplexity.ln();
    for i in 0..n {
        let neigh = knn(&tree, data.point(i), k, i);
        let d2: Vec<f64> = neigh.iter().map(|&(_, d)| d * d).collect();
        // Binary search the precision β for the target entropy.
        let mut beta = 1.0f64;
        let mut lo = 0.0f64;
        let mut hi = f64::INFINITY;
        let mut probs = vec![0.0; neigh.len()];
        for _ in 0..64 {
            let mut sum = 0.0;
            let dmin = d2.iter().cloned().fold(f64::INFINITY, f64::min);
            for (t, &dd) in d2.iter().enumerate() {
                probs[t] = (-beta * (dd - dmin)).exp();
                sum += probs[t];
            }
            let mut entropy = 0.0;
            for p in probs.iter_mut() {
                *p /= sum;
                if *p > 1e-300 {
                    entropy -= *p * p.ln();
                }
            }
            let diff = entropy - target_entropy;
            if diff.abs() < 1e-5 {
                break;
            }
            if diff > 0.0 {
                lo = beta;
                beta = if hi.is_finite() { 0.5 * (beta + hi) } else { beta * 2.0 };
            } else {
                hi = beta;
                beta = 0.5 * (beta + lo);
            }
        }
        cond_cols.push(neigh.iter().map(|&(j, _)| j as u32).collect());
        cond_vals.push(probs);
    }
    // Symmetrize: p_ij = (p_{j|i} + p_{i|j}) / (2N), union sparsity.
    use std::collections::HashMap;
    let mut maps: Vec<HashMap<u32, f64>> = vec![HashMap::new(); n];
    for i in 0..n {
        for (t, &j) in cond_cols[i].iter().enumerate() {
            let v = cond_vals[i][t] / (2.0 * n as f64);
            *maps[i].entry(j).or_insert(0.0) += v;
            *maps[j as usize].entry(i as u32).or_insert(0.0) += v;
        }
    }
    let mut cols = Vec::with_capacity(n);
    let mut vals = Vec::with_capacity(n);
    for map in maps {
        let mut row: Vec<(u32, f64)> = map.into_iter().collect();
        row.sort_unstable_by_key(|&(j, _)| j);
        cols.push(row.iter().map(|&(j, _)| j).collect());
        vals.push(row.iter().map(|&(_, v)| v).collect());
    }
    Affinities { cols, vals }
}

/// The repulsive field and partition function via three kernel MVMs.
///
/// Returns (rep_x, rep_y, Z) with
/// `rep_i = Σ_j w_ij² (y_i − y_j)` (division by Z left to the caller).
pub fn repulsive_field(
    embedding: &Points,
    cfg: &TsneConfig,
    session: &Session,
) -> (Vec<f64>, Vec<f64>, f64) {
    let n = embedding.len();
    if cfg.exact_repulsion {
        let mut rep = vec![0.0; 2 * n];
        let mut z = 0.0;
        for i in 0..n {
            let yi = embedding.point(i);
            for j in 0..n {
                if i == j {
                    continue;
                }
                let yj = embedding.point(j);
                let d2 = crate::linalg::vecops::dist2(yi, yj);
                let w = 1.0 / (1.0 + d2);
                z += w;
                let w2 = w * w;
                rep[2 * i] += w2 * (yi[0] - yj[0]);
                rep[2 * i + 1] += w2 * (yi[1] - yj[1]);
            }
        }
        let (rx, ry): (Vec<f64>, Vec<f64>) = (
            (0..n).map(|i| rep[2 * i]).collect(),
            (0..n).map(|i| rep[2 * i + 1]).collect(),
        );
        return (rx, ry, z);
    }
    let ones = vec![1.0; n];
    let y0: Vec<f64> = (0..n).map(|i| embedding.point(i)[0]).collect();
    let y1: Vec<f64> = (0..n).map(|i| embedding.point(i)[1]).collect();
    // Z: Cauchy MVM with ones (subtracting the N diagonal terms).
    // Per-step operators are applied exactly once, so the far-field panel
    // cache could only add materialization overhead — force streaming.
    let cauchy = session
        .operator(embedding)
        .kernel(Family::Cauchy)
        .config(cfg.fkt)
        .panel_budget(0)
        .transient()
        .build();
    let s1 = session.mvm(&cauchy, &ones);
    let z: f64 = s1.iter().sum::<f64>() - n as f64;
    // Repulsion: the three squared-Cauchy MVMs with [1, y_x, y_y] fused
    // into one 3-column batch — a single tree traversal per gradient step
    // instead of three (the per-pair harmonics and radial jets are shared).
    let csq = session
        .operator(embedding)
        .kernel(Family::CauchySquared)
        .config(cfg.fkt)
        .panel_budget(0)
        .transient()
        .build();
    let mut wb = Vec::with_capacity(3 * n);
    wb.extend_from_slice(&ones);
    wb.extend_from_slice(&y0);
    wb.extend_from_slice(&y1);
    let abxy = session.mvm_batch(&csq, &wb, 3);
    let (a, rest) = abxy.split_at(n);
    let (bx, by) = rest.split_at(n);
    let mut rx = vec![0.0; n];
    let mut ry = vec![0.0; n];
    for i in 0..n {
        // Subtract the self term w_ii²·(…)=1·0 — already zero.
        rx[i] = (a[i] - 1.0) * y0[i] - (bx[i] - y0[i]);
        ry[i] = (a[i] - 1.0) * y1[i] - (by[i] - y1[i]);
    }
    (rx, ry, z)
}

/// Result of a t-SNE run.
pub struct TsneResult {
    /// Final 2-D embedding.
    pub embedding: Points,
    /// KL divergence trace (sampled every 25 iterations).
    pub kl_trace: Vec<(usize, f64)>,
}

/// Run t-SNE on `data`, returning the 2-D embedding.
pub fn run(data: &Points, cfg: &TsneConfig, session: &Session) -> TsneResult {
    let n = data.len();
    let aff = compute_affinities(data, cfg.perplexity);
    let mut rng = Pcg32::seeded(cfg.seed);
    let mut y: Vec<f64> = (0..2 * n).map(|_| 1e-4 * rng.normal()).collect();
    let mut vel = vec![0.0; 2 * n];
    let mut kl_trace = Vec::new();
    // One embedding buffer reused across gradient steps: each iteration
    // copies the current positions in place instead of allocating a fresh
    // O(N) `Points` per step (the repulsive field only needs a read-only
    // snapshot of `y`).
    let mut embedding = Points::new(2, vec![0.0; 2 * n]);
    for iter in 0..cfg.iterations {
        let exag = if iter < cfg.exaggeration_iters { cfg.exaggeration } else { 1.0 };
        let momentum = if iter < cfg.exaggeration_iters {
            cfg.momentum_early
        } else {
            cfg.momentum_late
        };
        embedding.coords.copy_from_slice(&y);
        let (rx, ry, z) = repulsive_field(&embedding, cfg, session);
        // Attractive term over the sparse P.
        let mut grad = vec![0.0; 2 * n];
        for i in 0..n {
            let yi = [y[2 * i], y[2 * i + 1]];
            let mut gx = 0.0;
            let mut gy = 0.0;
            for (t, &j) in aff.cols[i].iter().enumerate() {
                let j = j as usize;
                let dx = yi[0] - y[2 * j];
                let dy = yi[1] - y[2 * j + 1];
                let w = 1.0 / (1.0 + dx * dx + dy * dy);
                let c = exag * aff.vals[i][t] * w;
                gx += c * dx;
                gy += c * dy;
            }
            grad[2 * i] = 4.0 * (gx - rx[i] / z);
            grad[2 * i + 1] = 4.0 * (gy - ry[i] / z);
        }
        // Momentum update.
        for t in 0..2 * n {
            vel[t] = momentum * vel[t] - cfg.learning_rate * grad[t];
            y[t] += vel[t];
        }
        // Re-center (the objective is translation invariant).
        let (mut mx, mut my) = (0.0, 0.0);
        for i in 0..n {
            mx += y[2 * i];
            my += y[2 * i + 1];
        }
        mx /= n as f64;
        my /= n as f64;
        for i in 0..n {
            y[2 * i] -= mx;
            y[2 * i + 1] -= my;
        }
        if iter % 25 == 0 || iter + 1 == cfg.iterations {
            let kl = kl_divergence(&aff, &y, z);
            kl_trace.push((iter, kl));
        }
    }
    TsneResult { embedding: Points::new(2, y), kl_trace }
}

/// KL(P‖Q) over the sparse support of P (the dominant part of the
/// objective; the off-support contribution is O(p_ij → 0)).
pub fn kl_divergence(aff: &Affinities, y: &[f64], z: f64) -> f64 {
    let mut kl = 0.0;
    for i in 0..aff.cols.len() {
        for (t, &j) in aff.cols[i].iter().enumerate() {
            let j = j as usize;
            let p = aff.vals[i][t];
            if p <= 0.0 {
                continue;
            }
            let dx = y[2 * i] - y[2 * j];
            let dy = y[2 * i + 1] - y[2 * j + 1];
            let w = 1.0 / (1.0 + dx * dx + dy * dy);
            let q = (w / z).max(1e-300);
            kl += p * (p / q).ln();
        }
    }
    kl
}

/// kNN label purity of an embedding — the quantitative stand-in for the
/// qualitative Fig 3-right cluster plot.
pub fn knn_purity(embedding: &Points, labels: &[usize], k: usize) -> f64 {
    let tree = Tree::build(embedding, 32);
    let n = embedding.len();
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for (j, _) in knn(&tree, embedding.point(i), k, i) {
            if labels[j] == labels[i] {
                agree += 1;
            }
            total += 1;
        }
    }
    agree as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist_like;

    #[test]
    fn affinity_rows_are_calibrated() {
        let mut rng = Pcg32::seeded(231);
        let data = Points::new(5, rng.normal_vec(200 * 5));
        let aff = compute_affinities(&data, 15.0);
        // Rows sum to ~1/N each after symmetrization (total mass 1).
        let total: f64 = aff.vals.iter().flatten().sum();
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
        // Symmetry: p_ij == p_ji.
        for i in 0..200 {
            for (t, &j) in aff.cols[i].iter().enumerate() {
                let j = j as usize;
                let pos = aff.cols[j].binary_search(&(i as u32)).expect("symmetric support");
                assert!((aff.vals[i][t] - aff.vals[j][pos]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fkt_repulsion_matches_exact() {
        let mut rng = Pcg32::seeded(232);
        let emb = Points::new(2, rng.normal_vec(400 * 2));
        let session = Session::native(2);
        let cfg_exact = TsneConfig { exact_repulsion: true, ..Default::default() };
        let cfg_fkt = TsneConfig {
            exact_repulsion: false,
            fkt: FktConfig { p: 5, theta: 0.4, leaf_capacity: 32, ..Default::default() },
            ..Default::default()
        };
        let (ex, ey, ez) = repulsive_field(&emb, &cfg_exact, &session);
        let (fx, fy, fz) = repulsive_field(&emb, &cfg_fkt, &session);
        assert!((ez - fz).abs() < 1e-3 * ez, "Z: {ez} vs {fz}");
        let norm: f64 = ex.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut err = 0.0;
        for i in 0..400 {
            err += (ex[i] - fx[i]).powi(2) + (ey[i] - fy[i]).powi(2);
        }
        let rel = err.sqrt() / norm;
        assert!(rel < 1e-3, "repulsion rel err {rel}");
    }

    #[test]
    fn fused_repulsion_matches_three_separate_mvms() {
        // The fused 3-column batch must reproduce the pre-fusion code path
        // (three independent squared-Cauchy MVMs) to round-off.
        let mut rng = Pcg32::seeded(235);
        let emb = Points::new(2, rng.normal_vec(500 * 2));
        let n = emb.len();
        let cfg = TsneConfig {
            exact_repulsion: false,
            fkt: FktConfig { p: 4, theta: 0.5, leaf_capacity: 64, ..Default::default() },
            ..Default::default()
        };
        let session = Session::native(2);
        let (fx, fy, _) = repulsive_field(&emb, &cfg, &session);
        // Pre-fusion reference: an identically-configured operator (the
        // deterministic build makes it numerically identical to the
        // transient one inside repulsive_field), three single-RHS MVMs.
        let ones = vec![1.0; n];
        let y0: Vec<f64> = (0..n).map(|i| emb.point(i)[0]).collect();
        let y1: Vec<f64> = (0..n).map(|i| emb.point(i)[1]).collect();
        let csq =
            session.operator(&emb).kernel(Family::CauchySquared).config(cfg.fkt).build();
        let a = session.mvm(&csq, &ones);
        let bx = session.mvm(&csq, &y0);
        let by = session.mvm(&csq, &y1);
        for i in 0..n {
            let rx = (a[i] - 1.0) * y0[i] - (bx[i] - y0[i]);
            let ry = (a[i] - 1.0) * y1[i] - (by[i] - y1[i]);
            assert!((fx[i] - rx).abs() <= 1e-10 * (1.0 + rx.abs()), "i={i}");
            assert!((fy[i] - ry).abs() <= 1e-10 * (1.0 + ry.abs()), "i={i}");
        }
    }

    #[test]
    fn kl_decreases_on_clustered_data() {
        let mut rng = Pcg32::seeded(233);
        let (data, _) = mnist_like(300, 10, &mut rng);
        let session = Session::native(2);
        let cfg = TsneConfig {
            iterations: 120,
            exaggeration_iters: 50,
            perplexity: 10.0,
            learning_rate: 100.0,
            exact_repulsion: true, // small N: exact is fastest & cleanest
            ..Default::default()
        };
        let res = run(&data, &cfg, &session);
        let first = res.kl_trace.first().unwrap().1;
        let last = res.kl_trace.last().unwrap().1;
        assert!(last < first, "KL did not decrease: {first} -> {last}");
    }

    #[test]
    fn embedding_separates_clusters() {
        let mut rng = Pcg32::seeded(234);
        let (data, labels) = mnist_like(400, 12, &mut rng);
        let session = Session::native(2);
        let cfg = TsneConfig {
            iterations: 250,
            exaggeration_iters: 100,
            perplexity: 15.0,
            learning_rate: 100.0,
            exact_repulsion: true,
            ..Default::default()
        };
        let res = run(&data, &cfg, &session);
        let purity = knn_purity(&res.embedding, &labels, 10);
        assert!(purity > 0.8, "embedding purity {purity}");
    }
}
