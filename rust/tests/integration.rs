//! Cross-module integration tests: the full session-fronted pipeline
//! against the dense oracle, tolerance-driven auto-tuning, operator-
//! registry reuse, GP/t-SNE end-to-end, and (when artifacts are built)
//! the PJRT seam. Application-level code goes through [`Session`] only —
//! no direct `FktOperator`/`Coordinator` construction anywhere here.

use fkt::baselines::dense_mvm;
use fkt::kernels::{Family, Kernel};
use fkt::points::Points;
use fkt::rng::Pcg32;
use fkt::session::{Backend, Session};

fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num / den.max(1e-300)).sqrt()
}

#[test]
fn full_pipeline_all_default_artifact_families() {
    // Every family the AOT artifact set ships must pass the dense check
    // through the session (native backend).
    let mut rng = Pcg32::seeded(401);
    let pts = Points::new(2, rng.uniform_vec(600 * 2, 0.0, 1.0));
    let w = rng.normal_vec(600);
    let session = Session::native(1);
    for fam in [
        Family::Cauchy,
        Family::CauchySquared,
        Family::Exponential,
        Family::Matern32,
        Family::Gaussian,
        Family::Coulomb,
    ] {
        let kern = Kernel::canonical(fam);
        let dense = dense_mvm(&kern, &pts, &pts, &w);
        let op = session.operator(&pts).kernel(fam).order(5).theta(0.5).leaf_capacity(50).build();
        let z = session.mvm(&op, &w);
        let e = rel_err(&z, &dense);
        assert!(e < 2e-3, "{fam:?}: rel err {e}");
    }
}

#[test]
fn tolerance_requests_meet_measured_error() {
    // The tentpole acceptance check: for Gaussian / Matérn-5/2 / Cauchy,
    // `.tolerance(ε)` must auto-tune (p, θ) such that the *measured*
    // relative error against the exact dense sum is ≤ ε.
    let mut rng = Pcg32::seeded(408);
    let pts = Points::new(2, rng.uniform_vec(700 * 2, 0.0, 1.0));
    let w = rng.normal_vec(700);
    let session = Session::native(2);
    for fam in [Family::Gaussian, Family::Matern52, Family::Cauchy] {
        let kern = Kernel::canonical(fam);
        let dense = dense_mvm(&kern, &pts, &pts, &w);
        for eps in [1e-2, 1e-4, 1e-6] {
            let op = session
                .operator(&pts)
                .kernel(fam)
                .tolerance(eps)
                .leaf_capacity(64)
                .build();
            let res = op.resolved().expect("tolerance must resolve");
            assert!(res.bound <= eps, "{fam:?} eps={eps}: bound {}", res.bound);
            let z = session.mvm(&op, &w);
            let e = rel_err(&z, &dense);
            assert!(
                e <= eps,
                "{fam:?} eps={eps}: measured {e} with resolved p={} theta={}",
                res.p,
                res.theta
            );
        }
    }
}

#[test]
fn tolerance_requests_meet_measured_error_3d_scaled() {
    // Same promise with a non-unit kernel scale and 3-D data: resolution
    // accounts for the scaled diameter, not the raw coordinates.
    let mut rng = Pcg32::seeded(409);
    let pts = Points::new(3, rng.uniform_vec(500 * 3, 0.0, 1.0));
    let w = rng.normal_vec(500);
    let kern = Kernel::matern32(0.8); // scale √3/0.8 ≈ 2.17
    let dense = dense_mvm(&kern, &pts, &pts, &w);
    let session = Session::native(2);
    for eps in [1e-3, 1e-5] {
        let op = session
            .operator(&pts)
            .scaled_kernel(kern)
            .tolerance(eps)
            .leaf_capacity(48)
            .build();
        let z = session.mvm(&op, &w);
        let e = rel_err(&z, &dense);
        assert!(e <= eps, "eps={eps}: measured {e} (resolved {:?})", op.resolved());
    }
}

#[test]
fn registry_reuses_operators_pointer_equal() {
    // Repeated requests against the same dataset must return the same
    // cached operator (pointer-equal Arc), with the hit counter advancing
    // and no extra build time accrued.
    let mut rng = Pcg32::seeded(410);
    let pts = Points::new(2, rng.uniform_vec(800 * 2, 0.0, 1.0));
    let session = Session::native(1);
    let first = session.operator(&pts).kernel(Family::Matern52).tolerance(1e-5).build();
    let stats_after_build = session.registry_stats();
    assert_eq!(stats_after_build.misses, 1);
    let built_seconds = stats_after_build.build_seconds;
    let second = session.operator(&pts).kernel(Family::Matern52).tolerance(1e-5).build();
    let third = session.operator(&pts).kernel(Family::Matern52).tolerance(1e-5).build();
    assert!(first.ptr_eq(&second), "cache hit must be pointer-equal");
    assert!(first.ptr_eq(&third));
    let stats = session.registry_stats();
    assert_eq!(stats.hits, 2, "hit-count metric");
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.build_seconds, built_seconds, "hits must not rebuild");
    // Different tolerance ⇒ possibly different (p, θ) ⇒ at most one more
    // build; same resolved config would legitimately hit again.
    let relaxed = session.operator(&pts).kernel(Family::Matern52).tolerance(1e-2).build();
    assert!(relaxed.resolved().expect("resolved").bound <= 1e-2);
}

#[test]
fn batched_mvm_matches_looped_through_session() {
    // The full multi-RHS pipeline: one 3-column mvm_batch equals three
    // looped session MVMs to ≤ 1e-12, in exactly one traversal,
    // across kernels and thread counts.
    let mut rng = Pcg32::seeded(405);
    let n = 900;
    let pts = Points::new(3, rng.uniform_vec(n * 3, 0.0, 1.0));
    let w = rng.normal_vec(n * 3);
    for fam in [Family::Cauchy, Family::Gaussian, Family::Matern32] {
        for threads in [1usize, 4, 7] {
            let session = Session::native(threads);
            let op =
                session.operator(&pts).kernel(fam).order(4).theta(0.5).leaf_capacity(64).build();
            let batched = session.mvm_batch(&op, &w, 3);
            assert_eq!(session.last_metrics().columns, 3);
            assert_eq!(session.last_metrics().moment_passes, 1, "{fam:?} threads={threads}");
            assert_eq!(session.last_metrics().far_passes, 1);
            assert_eq!(session.last_metrics().near_passes, 1);
            for c in 0..3 {
                let single = session.mvm(&op, &w[c * n..(c + 1) * n]);
                for t in 0..n {
                    let b = batched[c * n + t];
                    assert!(
                        (b - single[t]).abs() <= 1e-12 * (1.0 + single[t].abs()),
                        "{fam:?} threads={threads} col={c} t={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_rectangular_operator_through_session() {
    // GP-prediction shape (targets ≠ sources) through the full stack.
    let mut rng = Pcg32::seeded(406);
    let src = Points::new(2, rng.uniform_vec(500 * 2, 0.0, 1.0));
    let tgt = Points::new(2, rng.uniform_vec(170 * 2, 0.0, 1.0));
    let w = rng.normal_vec(500 * 2);
    for threads in [1usize, 4] {
        let session = Session::native(threads);
        let op = session
            .operator(&src)
            .targets(&tgt)
            .kernel(Family::Gaussian)
            .order(5)
            .theta(0.5)
            .leaf_capacity(40)
            .build();
        let batched = session.mvm_batch(&op, &w, 2);
        assert_eq!(batched.len(), 170 * 2);
        for c in 0..2 {
            let single = session.mvm(&op, &w[c * 500..(c + 1) * 500]);
            for t in 0..170 {
                let b = batched[c * 170 + t];
                assert!(
                    (b - single[t]).abs() <= 1e-12 * (1.0 + single[t].abs()),
                    "threads={threads} col={c} t={t}"
                );
            }
        }
    }
}

#[test]
fn dense_backend_swaps_in_through_session() {
    // Same session surface, two backends — consumers never name the
    // concrete operator type.
    let mut rng = Pcg32::seeded(407);
    let pts = Points::new(2, rng.uniform_vec(400 * 2, 0.0, 1.0));
    let w = rng.normal_vec(400);
    let session = Session::native(2);
    let exact = session.operator(&pts).kernel(Family::Cauchy).dense().build();
    let fast = session.operator(&pts).kernel(Family::Cauchy).order(6).theta(0.4).build();
    let ze = session.mvm(&exact, &w);
    let zf = session.mvm(&fast, &w);
    let e = rel_err(&zf, &ze);
    assert!(e < 1e-4, "backend mismatch {e}");
}

#[test]
fn solve_then_predict_gp_end_to_end() {
    use fkt::data::sst;
    use fkt::fkt::FktConfig;
    use fkt::gp::{GpConfig, GpRegressor};
    let mut rng = Pcg32::seeded(403);
    let ds = sst::simulate(1.0, 1500, &mut rng);
    let y = ds.temperatures();
    let mean_y: f64 = y.iter().sum::<f64>() / y.len() as f64;
    let y0: Vec<f64> = y.iter().map(|v| v - mean_y).collect();
    let cfg = GpConfig {
        fkt: FktConfig { p: 4, theta: 0.6, leaf_capacity: 128, ..Default::default() },
        cg_tol: 1e-5,
        cg_max_iters: 200,
        jitter: 1e-6,
        ..Default::default()
    };
    let session = Session::native(1);
    let mut gp = GpRegressor::new(
        &session,
        ds.unit_sphere_points(),
        ds.noise_variances(),
        Kernel::matern32(0.25),
        cfg,
    );
    let (grid, coords) = sst::prediction_grid(12, 36, 60.0);
    let res = gp.posterior_mean(&y0, &grid, &session);
    assert!(res.cg.converged, "CG residual {}", res.cg.rel_residual);
    // Posterior should beat the mean-only baseline handily.
    let mut se = 0.0;
    let mut base = 0.0;
    for (i, &(lat, lon)) in coords.iter().enumerate() {
        let truth = sst::true_field(lat, lon);
        se += (res.mean[i] + mean_y - truth).powi(2);
        base += (mean_y - truth).powi(2);
    }
    assert!(se < 0.05 * base, "rmse ratio {}", (se / base).sqrt());
    // A second posterior mean over the same grid reuses both cached
    // operators AND the cached representer weights — only registry hits,
    // no new builds, ZERO additional solves.
    let misses_before = session.registry_stats().misses;
    let solves_before = session.counters().solve;
    let res2 = gp.posterior_mean(&y0, &grid, &session);
    assert_eq!(session.registry_stats().misses, misses_before, "warm predict rebuilds nothing");
    assert_eq!(session.counters().solve, solves_before, "warm predict re-solves nothing");
    assert!(res2.cg.cached, "second fit served from the weight cache");
    for (a, b) in res.mean.iter().zip(&res2.mean) {
        assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
    }
}

#[test]
fn gp_training_end_to_end_through_session_verbs() {
    // Small end-to-end: train on synthetic Matérn-3/2 data, then predict
    // with the trained regressor — all through one session, with the
    // per-iteration cost invariants visible in the verb counters.
    use fkt::fkt::FktConfig;
    use fkt::gp::{GpConfig, GpRegressor, TrainOpts};
    let mut rng = Pcg32::seeded(411);
    let n = 400;
    let pts = Points::new(2, rng.uniform_vec(n * 2, 0.0, 1.0));
    // y from a smooth function + noise (length-scale ≈ 0.15 flavor).
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let p = pts.point(i);
            (9.0 * p[0]).sin() * (7.0 * p[1]).cos() + 0.3 * rng.normal()
        })
        .collect();
    let cfg = GpConfig {
        fkt: FktConfig { p: 4, theta: 0.5, leaf_capacity: 48, ..Default::default() },
        cg_tol: 1e-5,
        cg_max_iters: 300,
        jitter: 1e-8,
        ..Default::default()
    };
    let session = Session::native(2);
    let mut gp = GpRegressor::new(
        &session,
        pts.clone(),
        vec![0.2; n],
        Kernel::matern32(0.4),
        cfg,
    );
    let c0 = session.counters();
    let opts = TrainOpts { iters: 10, probes: 4, seed: 77, ..Default::default() };
    let res = gp.train(&session, &y, &opts);
    let c1 = session.counters();
    assert_eq!(c1.solve_batch - c0.solve_batch, 10, "one batched solve per iteration");
    assert_eq!(c1.solve, c0.solve, "no single-RHS solves on the training path");
    assert!(res.kernel.scale > 0.0 && res.noise_var > 0.0);
    // The trained regressor predicts through the refreshed operator.
    let pred = gp.posterior_mean(&y, &pts, &session);
    assert!(pred.cg.converged, "post-training fit converges");
}

#[test]
fn tsne_pipeline_smoke() {
    use fkt::fkt::FktConfig;
    use fkt::tsne::{knn_purity, run, TsneConfig};
    let mut rng = Pcg32::seeded(404);
    let (data, labels) = fkt::data::mnist_like(250, 8, &mut rng);
    let cfg = TsneConfig {
        iterations: 120,
        exaggeration_iters: 50,
        perplexity: 10.0,
        learning_rate: 80.0,
        fkt: FktConfig { p: 3, theta: 0.5, leaf_capacity: 64, ..Default::default() },
        exact_repulsion: false, // exercise the FKT repulsion path
        ..Default::default()
    };
    let session = Session::native(1);
    let res = run(&data, &cfg, &session);
    let purity = knn_purity(&res.embedding, &labels, 8);
    assert!(purity > 0.7, "purity {purity}");
    let first = res.kl_trace.first().unwrap().1;
    let last = res.kl_trace.last().unwrap().1;
    assert!(last < first, "KL {first} -> {last}");
    // t-SNE's per-iteration operators are transient: the registry must be
    // completely untouched (no dead entries retained, nothing evicted).
    let stats = session.registry_stats();
    assert_eq!(stats.len, 0, "transient t-SNE operators must not be cached");
    assert_eq!(stats.misses, 0);
    assert_eq!(stats.evictions, 0);
}

#[test]
fn pjrt_backend_end_to_end_when_artifacts_built() {
    let session = Session::builder().threads(1).backend(Backend::Pjrt).build();
    if !session.will_use_pjrt("gaussian", 3) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Pcg32::seeded(402);
    let pts = Points::new(3, rng.uniform_vec(700 * 3, 0.0, 1.0));
    let w = rng.normal_vec(700);
    let kern = Kernel::canonical(Family::Gaussian);
    let dense = dense_mvm(&kern, &pts, &pts, &w);
    let op = session
        .operator(&pts)
        .kernel(Family::Gaussian)
        .order(5)
        .theta(0.5)
        .leaf_capacity(80)
        .build();
    let z = session.mvm(&op, &w);
    assert!(session.last_metrics().used_pjrt);
    let e = rel_err(&z, &dense);
    assert!(e < 2e-3, "pjrt pipeline rel err {e}");
}
