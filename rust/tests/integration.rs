//! Cross-module integration tests: the full operator pipeline against the
//! dense oracle, coordinator backends, GP end-to-end, and (when artifacts
//! are built) the PJRT seam.

use fkt::baselines::dense_mvm;
use fkt::coordinator::{Backend, Coordinator, CoordinatorConfig};
use fkt::fkt::{FktConfig, FktOperator};
use fkt::kernels::{Family, Kernel};
use fkt::points::Points;
use fkt::rng::Pcg32;

fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num / den.max(1e-300)).sqrt()
}

#[test]
fn full_pipeline_all_default_artifact_families() {
    // Every family the AOT artifact set ships must pass the dense check
    // through the coordinator (native backend).
    let mut rng = Pcg32::seeded(401);
    let pts = Points::new(2, rng.uniform_vec(600 * 2, 0.0, 1.0));
    let w = rng.normal_vec(600);
    let mut coord = Coordinator::native(1);
    for fam in [
        Family::Cauchy,
        Family::CauchySquared,
        Family::Exponential,
        Family::Matern32,
        Family::Gaussian,
        Family::Coulomb,
    ] {
        let kern = Kernel::canonical(fam);
        let dense = dense_mvm(&kern, &pts, &pts, &w);
        let cfg = FktConfig { p: 5, theta: 0.5, leaf_capacity: 50, ..Default::default() };
        let op = FktOperator::square(&pts, kern, cfg);
        let z = coord.mvm(&op, &w);
        let e = rel_err(&z, &dense);
        assert!(e < 2e-3, "{fam:?}: rel err {e}");
    }
}

#[test]
fn pjrt_backend_end_to_end_when_artifacts_built() {
    let mut coord = Coordinator::new(CoordinatorConfig { threads: 1, backend: Backend::Pjrt });
    if !coord.will_use_pjrt("gaussian", 3) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Pcg32::seeded(402);
    let pts = Points::new(3, rng.uniform_vec(700 * 3, 0.0, 1.0));
    let w = rng.normal_vec(700);
    let kern = Kernel::canonical(Family::Gaussian);
    let dense = dense_mvm(&kern, &pts, &pts, &w);
    let cfg = FktConfig { p: 5, theta: 0.5, leaf_capacity: 80, ..Default::default() };
    let op = FktOperator::square(&pts, kern, cfg);
    let z = coord.mvm(&op, &w);
    assert!(coord.last_metrics.used_pjrt);
    let e = rel_err(&z, &dense);
    assert!(e < 2e-3, "pjrt pipeline rel err {e}");
}

#[test]
fn batched_mvm_matches_looped_through_coordinator() {
    // The full multi-RHS pipeline: one 3-column mvm_batch equals three
    // looped coordinator MVMs to ≤ 1e-12, in exactly one traversal,
    // across kernels and thread counts.
    let mut rng = Pcg32::seeded(405);
    let n = 900;
    let pts = Points::new(3, rng.uniform_vec(n * 3, 0.0, 1.0));
    let w = rng.normal_vec(n * 3);
    for fam in [Family::Cauchy, Family::Gaussian, Family::Matern32] {
        let kern = Kernel::canonical(fam);
        let cfg = FktConfig { p: 4, theta: 0.5, leaf_capacity: 64, ..Default::default() };
        let op = FktOperator::square(&pts, kern, cfg);
        for threads in [1usize, 4, 7] {
            let mut coord = Coordinator::native(threads);
            let batched = coord.mvm_batch(&op, &w, 3);
            assert_eq!(coord.last_metrics.columns, 3);
            assert_eq!(coord.last_metrics.moment_passes, 1, "{fam:?} threads={threads}");
            assert_eq!(coord.last_metrics.far_passes, 1);
            assert_eq!(coord.last_metrics.near_passes, 1);
            for c in 0..3 {
                let single = coord.mvm(&op, &w[c * n..(c + 1) * n]);
                for t in 0..n {
                    let b = batched[c * n + t];
                    assert!(
                        (b - single[t]).abs() <= 1e-12 * (1.0 + single[t].abs()),
                        "{fam:?} threads={threads} col={c} t={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_rectangular_operator_through_coordinator() {
    // GP-prediction shape (targets ≠ sources) through the full stack.
    let mut rng = Pcg32::seeded(406);
    let src = Points::new(2, rng.uniform_vec(500 * 2, 0.0, 1.0));
    let tgt = Points::new(2, rng.uniform_vec(170 * 2, 0.0, 1.0));
    let w = rng.normal_vec(500 * 2);
    let kern = Kernel::canonical(Family::Gaussian);
    let cfg = FktConfig { p: 5, theta: 0.5, leaf_capacity: 40, ..Default::default() };
    let op = FktOperator::new(&src, Some(&tgt), kern, cfg);
    for threads in [1usize, 4] {
        let mut coord = Coordinator::native(threads);
        let batched = coord.mvm_batch(&op, &w, 2);
        assert_eq!(batched.len(), 170 * 2);
        for c in 0..2 {
            let single = coord.mvm(&op, &w[c * 500..(c + 1) * 500]);
            for t in 0..170 {
                let b = batched[c * 170 + t];
                assert!(
                    (b - single[t]).abs() <= 1e-12 * (1.0 + single[t].abs()),
                    "threads={threads} col={c} t={t}"
                );
            }
        }
    }
}

#[test]
fn dense_backend_swaps_in_through_kernel_op() {
    use fkt::baselines::DenseOperator;
    use fkt::op::KernelOp;
    let mut rng = Pcg32::seeded(407);
    let pts = Points::new(2, rng.uniform_vec(400 * 2, 0.0, 1.0));
    let w = rng.normal_vec(400);
    let kern = Kernel::canonical(Family::Cauchy);
    let mut coord = Coordinator::native(2);
    let dense_op = DenseOperator::square(&pts, kern);
    let fkt_op = FktOperator::square(
        &pts,
        kern,
        FktConfig { p: 6, theta: 0.4, leaf_capacity: 32, ..Default::default() },
    );
    // Same call site, two backends — the coordinator only sees KernelOp.
    let ops: [&dyn KernelOp; 2] = [&dense_op, &fkt_op];
    let results: Vec<Vec<f64>> = ops.iter().map(|op| coord.mvm(*op, &w)).collect();
    let e = rel_err(&results[1], &results[0]);
    assert!(e < 1e-4, "backend mismatch {e}");
}

#[test]
fn gp_end_to_end_smoke() {
    use fkt::data::sst;
    use fkt::gp::{GpConfig, GpRegressor};
    let mut rng = Pcg32::seeded(403);
    let ds = sst::simulate(1.0, 1500, &mut rng);
    let y = ds.temperatures();
    let mean_y: f64 = y.iter().sum::<f64>() / y.len() as f64;
    let y0: Vec<f64> = y.iter().map(|v| v - mean_y).collect();
    let cfg = GpConfig {
        fkt: FktConfig { p: 4, theta: 0.6, leaf_capacity: 128, ..Default::default() },
        cg_tol: 1e-5,
        cg_max_iters: 200,
        jitter: 1e-6,
        precondition: true,
    };
    let gp = GpRegressor::new(ds.unit_sphere_points(), ds.noise_variances(), Kernel::matern32(0.25), cfg);
    let mut coord = Coordinator::native(1);
    let (grid, coords) = sst::prediction_grid(12, 36, 60.0);
    let res = gp.posterior_mean(&y0, &grid, &mut coord);
    assert!(res.cg.converged, "CG residual {}", res.cg.rel_residual);
    // Posterior should beat the mean-only baseline handily.
    let mut se = 0.0;
    let mut base = 0.0;
    for (i, &(lat, lon)) in coords.iter().enumerate() {
        let truth = sst::true_field(lat, lon);
        se += (res.mean[i] + mean_y - truth).powi(2);
        base += (mean_y - truth).powi(2);
    }
    assert!(se < 0.05 * base, "rmse ratio {}", (se / base).sqrt());
}

#[test]
fn tsne_pipeline_smoke() {
    use fkt::tsne::{knn_purity, run, TsneConfig};
    let mut rng = Pcg32::seeded(404);
    let (data, labels) = fkt::data::mnist_like(250, 8, &mut rng);
    let cfg = TsneConfig {
        iterations: 120,
        exaggeration_iters: 50,
        perplexity: 10.0,
        learning_rate: 80.0,
        fkt: FktConfig { p: 3, theta: 0.5, leaf_capacity: 64, ..Default::default() },
        exact_repulsion: false, // exercise the FKT repulsion path
        ..Default::default()
    };
    let mut coord = Coordinator::native(1);
    let res = run(&data, &cfg, &mut coord);
    let purity = knn_purity(&res.embedding, &labels, 8);
    assert!(purity > 0.7, "purity {purity}");
    let first = res.kl_trace.first().unwrap().1;
    let last = res.kl_trace.last().unwrap().1;
    assert!(last < first, "KL {first} -> {last}");
}
