//! Serving-layer integration tests: micro-batching equivalence against
//! the sequential session verbs, and full TCP round-trips through the
//! length-prefixed JSON protocol — all through the public `fkt::serve`
//! surface, the way a deployment would use it.

use fkt::kernels::Family;
use fkt::points::Points;
use fkt::rng::Pcg32;
use fkt::serve::{
    msg, soak, BatchConfig, BatchError, BreakerConfig, Client, FaultConfig, Faults, Json,
    MicroBatcher, MvmRequest, RetryPolicy, ServeConfig, Server, SoakConfig,
};
use fkt::fkt::FktConfig;
use fkt::session::{Backend, Session, Subsets};
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn l2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Eight concurrent tenants through one micro-batcher: every answer
/// matches the sequential verb to 1e-12, and the session's own verb
/// counters prove the batcher needed fewer apply passes than requests.
#[test]
fn batched_serving_matches_sequential_with_fewer_applies() {
    const CLIENTS: usize = 8;
    const N: usize = 500;
    let mut rng = Pcg32::seeded(31_000);
    let pts = Points::new(3, rng.uniform_vec(N * 3, 0.0, 1.0));
    let session = Session::native(1);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let weights: Vec<Vec<f64>> = (0..CLIENTS).map(|_| rng.normal_vec(N)).collect();
    let sequential: Vec<Vec<f64>> = weights.iter().map(|w| session.mvm(&op, w)).collect();
    let before = session.counters();

    // A wide gather window so the barrier-released burst lands in one
    // (or few) fused applies.
    let cfg = BatchConfig {
        max_columns: CLIENTS,
        gather_window: Duration::from_millis(150),
        ..BatchConfig::default()
    };
    let batcher = MicroBatcher::new(session.clone_core(), op, cfg);
    let barrier = Barrier::new(CLIENTS);
    let served: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = weights
            .iter()
            .map(|w| {
                let batcher = &batcher;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    batcher.mvm(w).expect("healthy batcher answers")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (got, want) in served.iter().zip(&sequential) {
        let err = l2(got, want);
        assert!(err <= 1e-12, "served column must match sequential mvm (l2 {err:.3e})");
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, CLIENTS as u64);
    assert!(
        stats.applies < stats.requests,
        "micro-batching must coalesce: {} applies for {} requests",
        stats.applies,
        stats.requests
    );
    // The same story from the session's side: fused verb invocations,
    // not per-request traversals.
    let after = session.counters();
    let verb_calls = (after.mvm - before.mvm) + (after.mvm_batch - before.mvm_batch);
    assert!(
        verb_calls < CLIENTS as u64,
        "{verb_calls} session verb calls should serve {CLIENTS} requests"
    );
}

fn local_reference(n: usize, seed: u64) -> (Session, Points) {
    let mut rng = Pcg32::seeded(seed);
    let pts = fkt::data::uniform_hypersphere(n, 3, &mut rng);
    let session = Session::builder().threads(1).backend(Backend::Auto).build();
    (session, pts)
}

fn open_request(n: usize) -> Json {
    msg(
        "open",
        &[
            ("name", Json::str("uniform")),
            ("n", Json::Num(n as f64)),
            ("d", Json::Num(3.0)),
            ("seed", Json::Num(9.0)),
            ("kernel", Json::str("matern32")),
            ("p", Json::Num(4.0)),
            ("theta", Json::Num(0.5)),
        ],
    )
}

/// Full TCP round-trip: open, mvm against a local reference, a
/// regularized solve to convergence, stats, protocol-level errors, close.
#[test]
fn tcp_round_trip_serves_correct_answers() {
    const N: usize = 1200;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 4,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server");
    let mut client = Client::connect(server.addr()).expect("connect");

    let opened = client.call_ok(&open_request(N)).expect("open");
    let id = opened.get("id").and_then(Json::as_usize).expect("id") as u64;
    assert_eq!(opened.get("n").and_then(Json::as_usize), Some(N));

    // Same dataset + spec built locally: the served mvm must agree.
    let (session, pts) = local_reference(N, 9);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let mut rng = Pcg32::seeded(77);
    let w = rng.normal_vec(N);
    let z_remote = client.mvm(id, &w).expect("mvm");
    let z_local = session.mvm(&op, &w);
    let err = l2(&z_remote, &z_local) / norm(&z_local).max(1e-300);
    assert!(err <= 1e-12, "served mvm must match local build (rel l2 {err:.3e})");

    let y = rng.normal_vec(N);
    let solve = msg(
        "solve",
        &[
            ("id", Json::Num(id as f64)),
            ("y", Json::from_f64s(&y)),
            ("noise", Json::Num(0.1)),
            ("tol", Json::Num(1e-6)),
            ("max_iters", Json::Num(400.0)),
        ],
    );
    let solved = client.call_ok(&solve).expect("solve");
    assert_eq!(solved.get("converged").and_then(Json::as_bool), Some(true));
    let x = solved.get("x").and_then(Json::f64s).expect("solution");
    // Verify the solution against the local operator: (K + σ²I)x ≈ y.
    let kx = session.mvm(&op, &x);
    let residual: Vec<f64> = kx
        .iter()
        .zip(&x)
        .zip(&y)
        .map(|((kxi, xi), yi)| kxi + 0.1 * xi - yi)
        .collect();
    let rel = norm(&residual) / norm(&y);
    assert!(rel <= 1e-4, "served solve must satisfy the system (rel residual {rel:.3e})");

    let stats = client.stats().expect("stats");
    let ops = stats.get("ops").and_then(Json::as_arr).expect("ops array");
    assert_eq!(ops.len(), 1, "one served operator");
    let registry = stats.get("registry").expect("registry stats");
    assert_eq!(registry.get("misses").and_then(Json::as_usize), Some(1));

    // Protocol errors come back as ok:false, not hangups.
    let bad_id = client.call(&msg("mvm", &[("id", Json::Num(999.0))])).expect("frame");
    assert_eq!(bad_id.get("ok").and_then(Json::as_bool), Some(false));
    let short = msg("mvm", &[("id", Json::Num(id as f64)), ("w", Json::from_f64s(&[1.0]))]);
    let short = client.call(&short).expect("frame");
    assert_eq!(short.get("ok").and_then(Json::as_bool), Some(false));
    let unknown = client.call(&msg("frobnicate", &[])).expect("frame");
    assert_eq!(unknown.get("ok").and_then(Json::as_bool), Some(false));
    // The connection survived all three errors.
    assert_eq!(client.mvm(id, &w).expect("post-error mvm").len(), N);

    client.close();
    server.shutdown().expect("clean shutdown");
}

/// Concurrent TCP tenants against one operator: every client gets the
/// right answer, the server hands all of them the same operator id, and
/// the per-op stats show cross-connection coalescing.
#[test]
fn concurrent_tcp_clients_share_one_batcher() {
    const CLIENTS: usize = 6;
    const N: usize = 600;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 4,
        batch: BatchConfig {
            max_columns: CLIENTS,
            gather_window: Duration::from_millis(60),
            ..BatchConfig::default()
        },
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server");

    let (session, pts) = local_reference(N, 9);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let weights: Vec<Vec<f64>> = {
        let mut rng = Pcg32::seeded(500);
        (0..CLIENTS).map(|_| rng.normal_vec(N)).collect()
    };
    let expected: Vec<Vec<f64>> = weights.iter().map(|w| session.mvm(&op, w)).collect();

    let addr = server.addr();
    let barrier = Barrier::new(CLIENTS);
    let outcomes: Vec<(u64, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = weights
            .iter()
            .map(|w| {
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    let id = client
                        .call_ok(&open_request(N))
                        .expect("open")
                        .get("id")
                        .and_then(Json::as_usize)
                        .expect("id") as u64;
                    barrier.wait();
                    let z = client.mvm(id, w).expect("mvm");
                    client.close();
                    (id, z)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let first_id = outcomes[0].0;
    for ((id, z), want) in outcomes.iter().zip(&expected) {
        assert_eq!(*id, first_id, "identical specs must share one operator id");
        let err = l2(z, want);
        assert!(err <= 1e-12, "concurrent served mvm must be exact (l2 {err:.3e})");
    }

    let mut probe = Client::connect(addr).expect("connect probe");
    let stats = probe.stats().expect("stats");
    let ops = stats.get("ops").and_then(Json::as_arr).expect("ops");
    assert_eq!(ops.len(), 1, "six tenants, one served operator");
    let entry = &ops[0];
    let requests = entry.get("requests").and_then(Json::as_usize).unwrap();
    let applies = entry.get("applies").and_then(Json::as_usize).unwrap();
    assert_eq!(requests, CLIENTS, "all client requests routed through the batcher");
    assert!(
        applies < requests,
        "cross-connection batching must coalesce: {applies} applies for {requests} requests"
    );
    let registry = stats.get("registry").expect("registry");
    assert_eq!(
        registry.get("misses").and_then(Json::as_usize),
        Some(1),
        "one build serves every tenant"
    );
    probe.close();
    server.shutdown().expect("clean shutdown");
}

/// Two tenants opening the SAME additive (ANOVA) spec over a d = 12
/// dataset share one composite operator id — the Arc-pointer interning
/// behind the op table works for composites exactly as for plain FKT
/// handles, because the composite itself is one registry-cached Arc — and
/// the served mvm matches a locally built composite bit-for-bit. Without
/// `subsets`, d = 12 stays rejected.
#[test]
fn tenants_share_one_additive_composite() {
    const N: usize = 600;
    const D: usize = 12;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 8,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server");

    let open = msg(
        "open",
        &[
            ("name", Json::str("uniform")),
            ("n", Json::Num(N as f64)),
            ("d", Json::Num(D as f64)),
            ("seed", Json::Num(9.0)),
            ("kernel", Json::str("matern32")),
            ("p", Json::Num(4.0)),
            ("theta", Json::Num(0.5)),
            ("subsets", Json::str("0,1,2;3,4,5;6,7,8")),
        ],
    );
    let mut a = Client::connect(server.addr()).expect("connect a");
    let mut b = Client::connect(server.addr()).expect("connect b");
    let ra = a.call_ok(&open).expect("open a");
    let rb = b.call_ok(&open).expect("open b");
    let id_a = ra.get("id").and_then(Json::as_usize).expect("id a") as u64;
    let id_b = rb.get("id").and_then(Json::as_usize).expect("id b") as u64;
    assert_eq!(id_a, id_b, "same additive spec must share one composite operator");
    assert_eq!(ra.get("terms").and_then(Json::as_usize), Some(3));
    assert_eq!(rb.get("terms").and_then(Json::as_usize), Some(3));

    // The widened dimension cap is subsets-only: the same d without them
    // is still a structured rejection.
    let too_wide = msg(
        "open",
        &[
            ("name", Json::str("uniform")),
            ("n", Json::Num(N as f64)),
            ("d", Json::Num(D as f64)),
            ("seed", Json::Num(9.0)),
        ],
    );
    let rejected = a.call(&too_wide).expect("frame");
    assert_eq!(rejected.get("ok").and_then(Json::as_bool), Some(false));

    // Local reference: the same dataset generation, the same composite.
    let mut rng = Pcg32::seeded(9);
    let pts = fkt::data::uniform_hypersphere(N, D, &mut rng);
    let session = Session::builder().threads(1).backend(Backend::Auto).build();
    let op = session
        .additive(&pts)
        .kernel(Family::Matern32)
        .subsets(Subsets::Explicit(vec![vec![0, 1, 2], vec![3, 4, 5], vec![6, 7, 8]]))
        .config(FktConfig { p: 4, theta: 0.5, leaf_capacity: 512, ..Default::default() })
        .build();
    let mut wrng = Pcg32::seeded(78);
    let w = wrng.normal_vec(N);
    let z_local = session.mvm(&op, &w);
    let z_a = a.mvm(id_a, &w).expect("mvm a");
    let z_b = b.mvm(id_b, &w).expect("mvm b");
    for z in [&z_a, &z_b] {
        let err = l2(z, &z_local) / norm(&z_local).max(1e-300);
        assert!(err <= 1e-12, "served composite mvm must match local build (rel l2 {err:.3e})");
    }

    // One build serves both tenants: three term operators plus the
    // composite itself, each constructed exactly once.
    let stats = a.stats().expect("stats");
    let registry = stats.get("registry").expect("registry");
    assert_eq!(
        registry.get("misses").and_then(Json::as_usize),
        Some(4),
        "three terms + one composite, built once across tenants"
    );
    let ops = stats.get("ops").and_then(Json::as_arr).expect("ops");
    assert_eq!(ops.len(), 1, "two tenants, one served composite");
    a.close();
    b.close();
    server.shutdown().expect("clean shutdown");
}

/// A fused apply that panics must answer every member of its batch with
/// the structured `WorkerPanic` error — and the worker thread must
/// survive to serve the next request.
#[test]
fn worker_panic_answers_the_whole_batch_and_worker_survives() {
    const N: usize = 300;
    let mut rng = Pcg32::seeded(52_000);
    let pts = Points::new(3, rng.uniform_vec(N * 3, 0.0, 1.0));
    let session = Session::native(1);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let faults = Arc::new(Faults::new(FaultConfig { inject: true, ..FaultConfig::disabled() }));
    let cfg = BatchConfig {
        max_columns: 4,
        gather_window: Duration::from_millis(150),
        ..BatchConfig::default()
    };
    let batcher = MicroBatcher::with_faults(session.clone_core(), op, cfg, faults);

    // One request tagged to panic the fused apply, submitted alongside
    // clean ones inside the same gather window.
    let tagged = MvmRequest { w: rng.normal_vec(N), deadline: None, inject_panic: true };
    let tagged_rx = batcher.submit(tagged).expect("admitted");
    let clean_rxs: Vec<_> = (0..3)
        .map(|_| batcher.submit(MvmRequest::new(rng.normal_vec(N))).expect("admitted"))
        .collect();

    match tagged_rx.recv().unwrap() {
        Err(BatchError::WorkerPanic(msg)) => {
            assert!(msg.contains("injected fault"), "panic message must surface: {msg}");
        }
        other => panic!("tagged request must get WorkerPanic, got {other:?}"),
    }
    // Whatever batch each clean request landed in, it got a framed
    // answer: the panicked batch answers with the structured error, a
    // later healthy batch with the result. Nobody hangs.
    for rx in clean_rxs {
        match rx.recv().unwrap() {
            Ok(z) => assert_eq!(z.len(), N),
            Err(BatchError::WorkerPanic(_)) => {}
            other => panic!("unexpected clean-request outcome {other:?}"),
        }
    }
    let s = batcher.stats();
    assert!(s.worker_panics >= 1, "panic must be counted ({})", s.worker_panics);
    // The worker thread survived the panicked batch and still answers.
    let z = batcher.mvm(&rng.normal_vec(N)).expect("worker survives a panicked batch");
    assert_eq!(z.len(), N);
}

/// Reliability over TCP: expired deadlines answer deterministically,
/// request-tagged panics surface as structured `worker_panic` errors and
/// trip the per-operator breaker, and the breaker recovers through its
/// half-open probe once the cooldown elapses.
#[test]
fn tcp_reliability_deadline_breaker_trip_and_recovery() {
    const N: usize = 400;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 4,
        breaker: BreakerConfig {
            failure_threshold: 3,
            cooldown: Duration::from_millis(150),
            half_open_probes: 1,
        },
        faults: FaultConfig { inject: true, ..FaultConfig::disabled() },
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server");
    let mut client = Client::connect(server.addr()).expect("connect");
    let opened = client.call_ok(&open_request(N)).expect("open");
    let id = opened.get("id").and_then(Json::as_usize).expect("id") as f64;
    let mut rng = Pcg32::seeded(88);
    let w = Json::from_f64s(&rng.normal_vec(N));

    // An already-expired deadline is refused deterministically, before
    // the request ever reaches the batch queue.
    let expired = msg(
        "mvm",
        &[("id", Json::Num(id)), ("w", w.clone()), ("deadline_ms", Json::Num(-5.0))],
    );
    let refused = client.call(&expired).expect("frame");
    assert_eq!(refused.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(refused.get("error").and_then(Json::as_str), Some("deadline_exceeded"));

    // Three request-tagged panics in a row: structured errors each time,
    // then the breaker opens.
    let inject = msg(
        "mvm",
        &[("id", Json::Num(id)), ("w", w.clone()), ("inject", Json::str("panic"))],
    );
    for _ in 0..3 {
        let r = client.call(&inject).expect("frame");
        assert_eq!(r.get("error").and_then(Json::as_str), Some("worker_panic"));
    }
    let clean = msg("mvm", &[("id", Json::Num(id)), ("w", w.clone())]);
    let rejected = client.call(&clean).expect("frame");
    assert_eq!(rejected.get("error").and_then(Json::as_str), Some("breaker_open"));
    assert!(rejected.get("retry_after_ms").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);

    let stats = client.stats().expect("stats");
    let entry = &stats.get("ops").and_then(Json::as_arr).expect("ops")[0];
    let breaker = entry.get("breaker").expect("breaker stats");
    assert_eq!(breaker.get("state").and_then(Json::as_str), Some("open"));
    assert_eq!(entry.get("worker_panics").and_then(Json::as_usize), Some(3));

    // After the cooldown the half-open probe admits one clean request,
    // and its success closes the breaker again.
    std::thread::sleep(Duration::from_millis(220));
    let healed = client.call(&clean).expect("frame");
    assert_eq!(healed.get("ok").and_then(Json::as_bool), Some(true), "half-open probe succeeds");
    let stats = client.stats().expect("stats");
    let entry = &stats.get("ops").and_then(Json::as_arr).expect("ops")[0];
    let breaker = entry.get("breaker").expect("breaker stats");
    assert_eq!(breaker.get("state").and_then(Json::as_str), Some("closed"));
    assert!(breaker.get("trips").and_then(Json::as_usize).unwrap_or(0) >= 1);
    client.close();
    server.shutdown().expect("clean shutdown");
}

/// The chaos soak: probabilistic apply panics, injected latency, and
/// connection drops under eight concurrent clients. The reliability
/// contract: every request resolves to a framed response (no hangs, no
/// stranded transports), the admission queue stays within its cap, and
/// the server still shuts down cleanly.
#[test]
fn chaos_soak_every_request_gets_a_framed_response() {
    const N: usize = 300;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 4,
        batch: BatchConfig {
            max_columns: 4,
            gather_window: Duration::from_millis(5),
            max_queue: 16,
        },
        breaker: BreakerConfig {
            failure_threshold: 4,
            cooldown: Duration::from_millis(100),
            half_open_probes: 1,
        },
        faults: FaultConfig {
            panic_p: 0.05,
            latency: Duration::from_millis(2),
            drop_p: 0.02,
            inject: true,
            ..FaultConfig::disabled()
        },
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server under faults");
    let soak_cfg = SoakConfig {
        clients: 8,
        requests_per_client: 12,
        open: open_request(N),
        weight_len: N,
        timeout: Duration::from_secs(30),
        ..SoakConfig::default()
    };
    let report = soak::run(server.addr(), &soak_cfg);
    assert_eq!(report.open_failures, 0, "every client must open through the retries");
    assert_eq!(report.total, 96);
    assert_eq!(report.hung, 0, "no request may hang under fault injection");
    assert_eq!(report.transport_failures, 0, "injected drops must be retried away");
    assert_eq!(report.framed(), report.total, "every request resolved to a framed response");
    assert!(report.error_rate() < 0.5, "error rate {:.3}", report.error_rate());

    let mut probe = Client::connect(server.addr()).expect("probe connect");
    let stats = probe
        .call_retry(&msg("stats", &[]), &RetryPolicy::default())
        .expect("stats under faults");
    assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true));
    let cap = stats
        .get("config")
        .and_then(|c| c.get("queue_cap"))
        .and_then(Json::as_usize)
        .expect("queue cap");
    for op in stats.get("ops").and_then(Json::as_arr).expect("ops") {
        let depth = op.get("queue_depth").and_then(Json::as_usize).unwrap_or(0);
        assert!(depth <= cap, "queue depth {depth} within cap {cap}");
    }
    let injected = stats
        .get("faults")
        .and_then(|f| f.get("injected_latency"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    assert!(injected >= 1, "the fault facility must actually have fired");
    probe.close();
    server.shutdown().expect("clean shutdown under faults");
}
