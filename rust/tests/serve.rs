//! Serving-layer integration tests: micro-batching equivalence against
//! the sequential session verbs, and full TCP round-trips through the
//! length-prefixed JSON protocol — all through the public `fkt::serve`
//! surface, the way a deployment would use it.

use fkt::kernels::Family;
use fkt::points::Points;
use fkt::rng::Pcg32;
use fkt::serve::{msg, BatchConfig, Client, Json, MicroBatcher, ServeConfig, Server};
use fkt::session::{Backend, Session};
use std::sync::Barrier;
use std::time::Duration;

fn l2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Eight concurrent tenants through one micro-batcher: every answer
/// matches the sequential verb to 1e-12, and the session's own verb
/// counters prove the batcher needed fewer apply passes than requests.
#[test]
fn batched_serving_matches_sequential_with_fewer_applies() {
    const CLIENTS: usize = 8;
    const N: usize = 500;
    let mut rng = Pcg32::seeded(31_000);
    let pts = Points::new(3, rng.uniform_vec(N * 3, 0.0, 1.0));
    let session = Session::native(1);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let weights: Vec<Vec<f64>> = (0..CLIENTS).map(|_| rng.normal_vec(N)).collect();
    let sequential: Vec<Vec<f64>> = weights.iter().map(|w| session.mvm(&op, w)).collect();
    let before = session.counters();

    // A wide gather window so the barrier-released burst lands in one
    // (or few) fused applies.
    let cfg = BatchConfig { max_columns: CLIENTS, gather_window: Duration::from_millis(150) };
    let batcher = MicroBatcher::new(session.clone_core(), op, cfg);
    let barrier = Barrier::new(CLIENTS);
    let served: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = weights
            .iter()
            .map(|w| {
                let batcher = &batcher;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    batcher.mvm(w)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (got, want) in served.iter().zip(&sequential) {
        let err = l2(got, want);
        assert!(err <= 1e-12, "served column must match sequential mvm (l2 {err:.3e})");
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, CLIENTS as u64);
    assert!(
        stats.applies < stats.requests,
        "micro-batching must coalesce: {} applies for {} requests",
        stats.applies,
        stats.requests
    );
    // The same story from the session's side: fused verb invocations,
    // not per-request traversals.
    let after = session.counters();
    let verb_calls = (after.mvm - before.mvm) + (after.mvm_batch - before.mvm_batch);
    assert!(
        verb_calls < CLIENTS as u64,
        "{verb_calls} session verb calls should serve {CLIENTS} requests"
    );
}

fn local_reference(n: usize, seed: u64) -> (Session, Points) {
    let mut rng = Pcg32::seeded(seed);
    let pts = fkt::data::uniform_hypersphere(n, 3, &mut rng);
    let session = Session::builder().threads(1).backend(Backend::Auto).build();
    (session, pts)
}

fn open_request(n: usize) -> Json {
    msg(
        "open",
        &[
            ("name", Json::str("uniform")),
            ("n", Json::Num(n as f64)),
            ("d", Json::Num(3.0)),
            ("seed", Json::Num(9.0)),
            ("kernel", Json::str("matern32")),
            ("p", Json::Num(4.0)),
            ("theta", Json::Num(0.5)),
        ],
    )
}

/// Full TCP round-trip: open, mvm against a local reference, a
/// regularized solve to convergence, stats, protocol-level errors, close.
#[test]
fn tcp_round_trip_serves_correct_answers() {
    const N: usize = 1200;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 4,
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server");
    let mut client = Client::connect(server.addr()).expect("connect");

    let opened = client.call_ok(&open_request(N)).expect("open");
    let id = opened.get("id").and_then(Json::as_usize).expect("id") as u64;
    assert_eq!(opened.get("n").and_then(Json::as_usize), Some(N));

    // Same dataset + spec built locally: the served mvm must agree.
    let (session, pts) = local_reference(N, 9);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let mut rng = Pcg32::seeded(77);
    let w = rng.normal_vec(N);
    let z_remote = client.mvm(id, &w).expect("mvm");
    let z_local = session.mvm(&op, &w);
    let err = l2(&z_remote, &z_local) / norm(&z_local).max(1e-300);
    assert!(err <= 1e-12, "served mvm must match local build (rel l2 {err:.3e})");

    let y = rng.normal_vec(N);
    let solve = msg(
        "solve",
        &[
            ("id", Json::Num(id as f64)),
            ("y", Json::from_f64s(&y)),
            ("noise", Json::Num(0.1)),
            ("tol", Json::Num(1e-6)),
            ("max_iters", Json::Num(400.0)),
        ],
    );
    let solved = client.call_ok(&solve).expect("solve");
    assert_eq!(solved.get("converged").and_then(Json::as_bool), Some(true));
    let x = solved.get("x").and_then(Json::f64s).expect("solution");
    // Verify the solution against the local operator: (K + σ²I)x ≈ y.
    let kx = session.mvm(&op, &x);
    let residual: Vec<f64> = kx
        .iter()
        .zip(&x)
        .zip(&y)
        .map(|((kxi, xi), yi)| kxi + 0.1 * xi - yi)
        .collect();
    let rel = norm(&residual) / norm(&y);
    assert!(rel <= 1e-4, "served solve must satisfy the system (rel residual {rel:.3e})");

    let stats = client.stats().expect("stats");
    let ops = stats.get("ops").and_then(Json::as_arr).expect("ops array");
    assert_eq!(ops.len(), 1, "one served operator");
    let registry = stats.get("registry").expect("registry stats");
    assert_eq!(registry.get("misses").and_then(Json::as_usize), Some(1));

    // Protocol errors come back as ok:false, not hangups.
    let bad_id = client.call(&msg("mvm", &[("id", Json::Num(999.0))])).expect("frame");
    assert_eq!(bad_id.get("ok").and_then(Json::as_bool), Some(false));
    let short = msg("mvm", &[("id", Json::Num(id as f64)), ("w", Json::from_f64s(&[1.0]))]);
    let short = client.call(&short).expect("frame");
    assert_eq!(short.get("ok").and_then(Json::as_bool), Some(false));
    let unknown = client.call(&msg("frobnicate", &[])).expect("frame");
    assert_eq!(unknown.get("ok").and_then(Json::as_bool), Some(false));
    // The connection survived all three errors.
    assert_eq!(client.mvm(id, &w).expect("post-error mvm").len(), N);

    client.close();
    server.shutdown().expect("clean shutdown");
}

/// Concurrent TCP tenants against one operator: every client gets the
/// right answer, the server hands all of them the same operator id, and
/// the per-op stats show cross-connection coalescing.
#[test]
fn concurrent_tcp_clients_share_one_batcher() {
    const CLIENTS: usize = 6;
    const N: usize = 600;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        registry_capacity: 4,
        batch: BatchConfig { max_columns: CLIENTS, gather_window: Duration::from_millis(60) },
        ..ServeConfig::default()
    };
    let server = Server::spawn(&cfg).expect("spawn server");

    let (session, pts) = local_reference(N, 9);
    let op = session.operator(&pts).kernel(Family::Matern32).order(4).theta(0.5).build();
    let weights: Vec<Vec<f64>> = {
        let mut rng = Pcg32::seeded(500);
        (0..CLIENTS).map(|_| rng.normal_vec(N)).collect()
    };
    let expected: Vec<Vec<f64>> = weights.iter().map(|w| session.mvm(&op, w)).collect();

    let addr = server.addr();
    let barrier = Barrier::new(CLIENTS);
    let outcomes: Vec<(u64, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = weights
            .iter()
            .map(|w| {
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    let id = client
                        .call_ok(&open_request(N))
                        .expect("open")
                        .get("id")
                        .and_then(Json::as_usize)
                        .expect("id") as u64;
                    barrier.wait();
                    let z = client.mvm(id, w).expect("mvm");
                    client.close();
                    (id, z)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let first_id = outcomes[0].0;
    for ((id, z), want) in outcomes.iter().zip(&expected) {
        assert_eq!(*id, first_id, "identical specs must share one operator id");
        let err = l2(z, want);
        assert!(err <= 1e-12, "concurrent served mvm must be exact (l2 {err:.3e})");
    }

    let mut probe = Client::connect(addr).expect("connect probe");
    let stats = probe.stats().expect("stats");
    let ops = stats.get("ops").and_then(Json::as_arr).expect("ops");
    assert_eq!(ops.len(), 1, "six tenants, one served operator");
    let entry = &ops[0];
    let requests = entry.get("requests").and_then(Json::as_usize).unwrap();
    let applies = entry.get("applies").and_then(Json::as_usize).unwrap();
    assert_eq!(requests, CLIENTS, "all client requests routed through the batcher");
    assert!(
        applies < requests,
        "cross-connection batching must coalesce: {applies} applies for {requests} requests"
    );
    let registry = stats.get("registry").expect("registry");
    assert_eq!(
        registry.get("misses").and_then(Json::as_usize),
        Some(1),
        "one build serves every tenant"
    );
    probe.close();
    server.shutdown().expect("clean shutdown");
}
